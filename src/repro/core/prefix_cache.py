"""Shared-prefix page cache: a page-granular radix trie over prompt tokens.

Under real multi-user traffic most requests share long system-prompt
prefixes. Re-prefilling and re-storing those tokens per slot wastes both
compute (O(prompt/bucket) chunk forwards) and pool pages. This module keeps
a **radix/trie index over page-size token chunks**: each node is one pool
page whose KV holds the node's tokens at the node's absolute positions, so a
new request whose prompt starts with a cached chain can

* **alias** every fully-matched page (pure page-table indirection — the
  attention kernels never know; refcounts in
  :class:`repro.core.paged_kv.PageAllocator` keep aliased pages alive), and
* **copy-on-write** the page where it diverges mid-page: the matched prefix
  of the page is copied to a private page
  (:func:`repro.core.paged_kv.copy_pool_pages`) which the request then
  extends, while the cached source stays byte-identical for other readers.

Nodes live in one of three states:

* **resident** — ``node.page`` is a device pool page (refcount >= 1, one
  reference owned by the cache);
* **tier** — the page was *requantized* one container step narrower
  (fp -> int8 -> int4, ``core.page_store.QuantTierStore``) and parked on
  device: ``node.tier`` is a tier handle, the original page was freed. A
  hit still matches; admission restores the node into a fresh page
  carrying the narrower grid's rounding loss (the accuracy cost the adapt
  gate measures);
* **host** — the page's bytes were *demoted* to the host tier
  (``core.page_store``): ``node.host`` is a :class:`HostPageStore` handle,
  no device page is held. A hit through a host node still matches; admission
  *promotes* it back to a device page before aliasing. Restart restore
  (:func:`core.page_store.load_prefix_snapshot`) creates nodes directly in
  the host state.

Correctness invariants:

* only FULL pages are aliased — a sharer's first write position is always
  past every aliased page, so shared pages are never scattered to;
* partial nodes are leaves (a child chunk can only continue at the next
  page boundary, which requires its parent to be full);
* page content is position-dependent (RoPE is applied before the cache
  write), so a chain only ever matches prompts token-for-token from
  position 0 — exactly the lookup this trie implements;
* pages are only shared between identically-quantized configurations: the
  trie is namespaced by a **profile key** (the per-layer KV precision
  profile + scale mode), so an int8 chain can never back an int4 request;
* a chain may interleave resident and host nodes freely — demoting a
  mid-chain node leaves no hole because its bytes survive on the host tier;
  *destroying* a node (drop) stays leaf-only.

Eviction under pool pressure runs **requant -> demote -> drop**: first the
LRU cold page is requantized in place onto the quant tier (no host round
trip, lossy by one container step) when one is attached, then **demotion**
(LRU over unreferenced resident pages, any trie position) when a pager with
host room is attached, and the destructive LRU leaf-first drop last. Admission
pins the nodes of a hit (``node.pins``) so reclaim triggered by its own
promotions/allocations can never evict the chain out from under it. The
cache registers itself as the allocator's ``reclaim`` hook: pool pressure
spills cold prefixes to host memory (or drops them) instead of failing the
allocation.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from ..runtime.telemetry import MetricsRegistry, NullTracer, metric_attr
from .paged_kv import PageAllocator

__all__ = ["PrefixCache", "PrefixHit"]


@dataclasses.dataclass
class PrefixHit:
    """Result of a longest-prefix lookup.

    ``matched == len(nodes) * page_size + cow_valid``. ``nodes`` is the
    fully-matched chain (each node one FULL page, resident or host);
    ``cow_node`` (if any) is the cached page the query diverges inside — the
    caller must copy it (after promoting, if host) and may then treat its
    first ``cow_valid`` tokens as written. ``full_pages``/``cow_page``
    expose the device page ids (-1 for host nodes) for introspection.
    """

    matched: int = 0
    nodes: List["_Node"] = dataclasses.field(default_factory=list)
    cow_node: Optional["_Node"] = None
    cow_valid: int = 0

    @property
    def full_pages(self) -> List[int]:
        return [n.page for n in self.nodes]

    @property
    def cow_page(self) -> Optional[int]:
        return None if self.cow_node is None else self.cow_node.page


class _Node:
    """One cached page: ``tokens`` (<= page_size) stored at ``page`` (device,
    resident state) or behind ``host`` (host-tier handle, demoted state).

    Children are keyed by their full token tuple for O(1) exact-chunk
    descent; partial children (count < page_size) are leaves and are found
    by the best-common-prefix scan. ``pins`` counts in-flight admissions
    holding this node — eviction (demote AND drop) skips pinned nodes.
    ``hits`` counts lookup matches and feeds the heat-aware victim score
    (:meth:`PrefixCache._heat`).
    """

    __slots__ = ("tokens", "page", "host", "tier", "children", "parent",
                 "stamp", "pins", "hits")

    def __init__(self, tokens: Tuple[int, ...], page: int, parent,
                 stamp: int, host: Optional[int] = None):
        self.tokens = tokens
        self.page = page
        self.host = host
        self.tier: Optional[int] = None   # QuantTierStore handle (parked)
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent = parent
        self.stamp = stamp
        self.pins = 0
        self.hits = 0

    @property
    def count(self) -> int:
        return len(self.tokens)

    @property
    def resident(self) -> bool:
        return self.page >= 0


def _common_prefix(a: Sequence[int], b: Sequence[int]) -> int:
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


class PrefixCache:
    """Radix index of cached prompt pages over one server's page pool.

    The cache holds ONE allocator reference per RESIDENT cached page (taken
    at ``insert`` or promotion), on top of whatever slots reference it — so
    a resident page is evictable exactly when its refcount is 1. Host-state
    nodes hold a host-tier handle instead. ``pager`` (optional,
    :class:`repro.core.page_store.TieredPager`) enables the host tier:
    without it the cache degrades to PR-3 behavior (destructive eviction,
    resident-only nodes).
    """

    # registry-backed legacy counter attributes (telemetry.metric_attr):
    # ``cache.stats()`` and every historical reader keep working, but the
    # values live in ``self.metrics`` under "prefix.*"
    lookups = metric_attr("prefix.lookups")
    hits = metric_attr("prefix.hits")
    hit_tokens = metric_attr("prefix.hit_tokens")
    lookup_tokens = metric_attr("prefix.lookup_tokens")
    inserted_pages = metric_attr("prefix.inserted_pages")
    cow_copies = metric_attr("prefix.cow_copies")
    evictions = metric_attr("prefix.evictions")
    demotions = metric_attr("prefix.demotions")
    promotions = metric_attr("prefix.promotions")
    host_drops = metric_attr("prefix.host_drops")
    restored_pages = metric_attr("prefix.restored_pages")
    requants = metric_attr("prefix.requants")
    deepens = metric_attr("prefix.deepens")
    tier_promotions = metric_attr("prefix.tier_promotions")

    def __init__(self, allocator: PageAllocator, page_size: int,
                 profile_key: str = "", pager=None, tier=None,
                 heat_boost: int = 16, metrics: Optional[MetricsRegistry]
                 = None, tracer=None):
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        # telemetry first: counter attributes below are registry-backed
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else NullTracer()
        self.allocator = allocator
        self.page_size = page_size
        self.profile_key = profile_key
        self.pager = pager
        self.tier = tier             # optional QuantTierStore (--kv-adapt)
        # Victim picking is heat-aware, not pure LRU: each lookup hit is
        # worth ``heat_boost`` clock ticks of recency, so a hot old node
        # outlives a cold young one (see _heat).
        self.heat_boost = heat_boost
        self._roots: Dict[str, _Node] = {}
        self._clock = itertools.count()
        # instrumentation (benchmarks/serve read these; the zeroing here
        # initializes the "prefix.*" registry counters)
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.lookup_tokens = 0
        self.inserted_pages = 0
        self.cow_copies = 0          # bumped by the server after each copy
        self.evictions = 0           # destructive drops of RESIDENT pages
        self.demotions = 0           # resident -> host spills
        self.promotions = 0          # host -> resident refills
        self.host_drops = 0          # destructive drops of HOST pages
        self.restored_pages = 0      # nodes created from a snapshot
        self.requants = 0            # resident -> quant-tier narrowings
        self.deepens = 0             # tier pages narrowed a further step
        self.tier_promotions = 0     # quant-tier -> resident restores
        # requant events that happened before the FIRST host demotion —
        # None until a demotion occurs (the adapt bench gate reads this)
        self.requants_at_first_demotion: Optional[int] = None

    # -- internals ----------------------------------------------------------
    def _root(self, profile_key: Optional[str]) -> _Node:
        key = self.profile_key if profile_key is None else profile_key
        if key not in self._roots:
            self._roots[key] = _Node((), -1, None, next(self._clock))
        return self._roots[key]

    def _all_nodes(self) -> List[_Node]:
        out = []
        stack = list(self._roots.values())
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n.parent is not None:
                out.append(n)
        return out

    def _nodes(self) -> List[_Node]:
        return [n for n in self._all_nodes() if n.resident]

    @staticmethod
    def _detach(node: _Node) -> None:
        del node.parent.children[node.tokens]
        node.parent = None

    # -- stats --------------------------------------------------------------
    @property
    def num_pages(self) -> int:
        """Device pages currently retained by the cache (resident nodes)."""
        return len(self._nodes())

    @property
    def host_pages(self) -> int:
        """Cached pages currently demoted to the host tier."""
        return sum(1 for n in self._all_nodes() if n.host is not None)

    @property
    def tier_pages(self) -> int:
        """Cached pages currently parked (narrowed) in the quant tier."""
        return sum(1 for n in self._all_nodes() if n.tier is not None)

    def _droppable_pages(self) -> int:
        """Resident pages reclaimable by DESTRUCTIVE leaf-first eviction:
        refcount-1 unpinned nodes whose whole subtree is also reclaimable
        (an ancestor of a referenced page must stay, or the chain develops
        a hole while a reader still aliases the child)."""

        def count(node: _Node) -> Tuple[int, bool]:
            n, free = 0, True
            for c in node.children.values():
                cn, cfree = count(c)
                n += cn
                free &= cfree
            if node.pins:
                return n, False
            if node.resident:
                if free and self.allocator.refcount(node.page) == 1:
                    return n + 1, True
                return n, False
            return n, free
        return sum(count(r)[0] for r in self._roots.values())

    def _demotable_nodes(self) -> List[_Node]:
        """Resident refcount-1 unpinned nodes — demotion candidates (ANY
        trie position: a demoted mid-chain node leaves no hole)."""
        return [n for n in self._nodes()
                if not n.pins and self.allocator.refcount(n.page) == 1]

    def _heat(self, n: _Node) -> int:
        """Victim score for requant/demote/deepen order: the node's LRU
        stamp PLUS ``heat_boost`` clock ticks per lifetime lookup hit, so a
        frequently-reused old node scores hotter than a recently-inserted
        never-hit one. Lowest score is picked first (coldest)."""
        return n.stamp + self.heat_boost * n.hits

    def evictable_pages(self) -> int:
        """Device pages reclaimable right now — by requantization onto the
        quant tier (byte room permitting), demotion (host room permitting),
        and/or destructive leaf-first drops."""
        drop = self._droppable_pages()
        demotable = len(self._demotable_nodes())
        room = self.tier.room_pages() if self.tier is not None else 0
        if self.pager is not None:
            host_room = self.pager.host_room()
            if host_room == float("inf"):
                return demotable
            room += int(host_room)
        # every droppable node is also demotable, so with no tier and no
        # pager this reduces to the plain droppable count
        return min(demotable, drop + room)

    def requantizable_pages(self) -> int:
        """Cold resident pages the quant tier could narrow + park right now
        (the ``OutOfPagesError.requantizable`` inventory)."""
        if self.tier is None:
            return 0
        return min(len(self._demotable_nodes()), self.tier.room_pages())

    # -- lookup -------------------------------------------------------------
    def lookup(self, tokens: Sequence[int],
               profile_key: Optional[str] = None,
               record: bool = True) -> PrefixHit:
        """Longest cached prefix of ``tokens`` (page-granular + intra-page).

        Pure read: no refcounts change, host nodes stay host. The caller
        pins the hit (:meth:`pin`) before any operation that could evict —
        lookup and pinning are adjacent, synchronous host work in the
        serving loop.

        ``record=False`` leaves the hit-rate counters untouched (the server
        passes it during admission, which may retry the same request every
        decode span while deferred, and records once on success via
        :meth:`note_lookup`); chain LRU stamps are refreshed either way,
        but per-node ``hits`` (the heat-score input) only count recorded
        lookups — once per admitted request, not once per retry.
        """
        tokens = [int(t) for t in tokens]
        if record:
            self.lookups += 1
            self.lookup_tokens += len(tokens)
        hit = PrefixHit()
        node = self._root(profile_key)
        ps = self.page_size
        i = 0
        while i < len(tokens):
            chunk = tuple(tokens[i:i + ps])
            child = node.children.get(chunk) if len(chunk) == ps else None
            if child is not None and child.count == ps:
                child.stamp = next(self._clock)
                if record:
                    child.hits += 1
                hit.nodes.append(child)
                hit.matched += ps
                node = child
                i += ps
                continue
            # diverging (or final sub-page) chunk: best intra-page match
            best, best_len = None, 0
            for c in node.children.values():
                n = _common_prefix(c.tokens, chunk)
                if n > best_len:
                    best, best_len = c, n
            if best is not None:
                best.stamp = next(self._clock)
                if record:
                    best.hits += 1
                hit.cow_node = best
                hit.cow_valid = best_len
                hit.matched += best_len
            break
        if record and hit.matched:
            self.hits += 1
            self.hit_tokens += hit.matched
        return hit

    def peek_chain(self, tokens: Sequence[int],
                   profile_key: Optional[str] = None) -> List["_Node"]:
        """The cached chain matching ``tokens`` — like :meth:`lookup` but a
        TRUE pure read: no LRU stamps, no hit counts, no clock ticks, and
        a missing root is not created. The promote-path prefetch scans
        next cycle's likely admissions with this, so staging host->device
        copies early can never perturb eviction order (and therefore
        token streams). Returns the fully-matched nodes plus the
        diverging (CoW) node, if any — the same set an admission of this
        prompt would have to make resident."""
        tokens = [int(t) for t in tokens]
        key = self.profile_key if profile_key is None else profile_key
        node = self._roots.get(key)
        out: List[_Node] = []
        if node is None:
            return out
        ps = self.page_size
        i = 0
        while i + ps <= len(tokens):
            child = node.children.get(tuple(tokens[i:i + ps]))
            if child is None or child.count != ps:
                break
            out.append(child)
            node = child
            i += ps
        chunk = tuple(tokens[i:i + ps])
        best, best_len = None, 0
        for c in node.children.values():
            n = _common_prefix(c.tokens, chunk)
            if n > best_len:
                best, best_len = c, n
        if best is not None:
            out.append(best)
        return out

    def note_lookup(self, n_tokens: int, matched: int) -> None:
        """Record one admission's hit-rate sample (pairs with
        ``lookup(record=False)``: counted once per ADMITTED request, not
        once per deferral retry)."""
        self.lookups += 1
        self.lookup_tokens += n_tokens
        if matched:
            self.hits += 1
            self.hit_tokens += matched

    # -- pinning / promotion ------------------------------------------------
    def _hit_nodes(self, hit: PrefixHit) -> List[_Node]:
        return hit.nodes + ([hit.cow_node] if hit.cow_node is not None
                            else [])

    def pin(self, hit: PrefixHit) -> None:
        """Shield a hit's chain from eviction (demote AND drop) while an
        admission is in flight. Balanced by :meth:`unpin`."""
        for n in self._hit_nodes(hit):
            n.pins += 1

    def unpin(self, hit: PrefixHit) -> None:
        for n in self._hit_nodes(hit):
            assert n.pins > 0, "unbalanced prefix-cache unpin"
            n.pins -= 1

    def pin_node(self, node: "_Node") -> None:
        """Pin ONE node across an arbitrary window (preemption re-aliasing
        holds a victim's aliased chain resident from preempt to resume —
        see ``launch.serve._preempt_slot``). Balanced by
        :meth:`unpin_node`."""
        node.pins += 1

    def unpin_node(self, node: "_Node") -> None:
        assert node.pins > 0, "unbalanced prefix-cache node unpin"
        node.pins -= 1

    def host_nodes_in(self, hit: PrefixHit) -> int:
        """Non-resident (host-state OR quant-tier) nodes an admission of
        this hit must promote — each costs one device page on top of the
        request's own demand."""
        return sum(1 for n in self._hit_nodes(hit) if not n.resident)

    def ensure_resident(self, node: _Node) -> int:
        """Promote ``node`` from the quant or host tier if needed; returns
        the device page id. Promotion allocates (may trigger reclaim
        pressure — safe, the caller pinned the chain, and pinned tier
        blobs are never deepened mid-restore). The promoted page's single
        reference belongs to the cache, exactly like a freshly inserted
        node. A quant-tier restore widens the narrowed grids back into the
        pools' native containers — the narrowing step's rounding loss is
        permanent (the adapt accuracy gate prices it)."""
        if node.resident:
            return node.page
        if node.tier is not None:
            page = self.allocator.alloc()
            self.tier.restore(node.tier, page)
            node.tier = None
            node.page = page
            self.tier_promotions += 1
            self.tracer.instant("prefix.tier_promote", args={"page": page})
            return page
        if self.pager is None:
            raise RuntimeError("host-state node without a pager")
        node.page = self.pager.promote(node.host)
        node.host = None
        self.promotions += 1
        self.tracer.instant("prefix.promote", args={"page": node.page})
        return node.page

    # -- insert -------------------------------------------------------------
    def insert(self, tokens: Sequence[int], pages: Sequence[int],
               profile_key: Optional[str] = None) -> int:
        """Index ``tokens`` (page-chunked into ``pages``) into the trie.

        ``pages[j]`` must hold the KV of ``tokens[j*ps:(j+1)*ps]`` at those
        absolute positions (the caller's prefill just wrote them, or they
        came from this cache). Chunks already cached are deduplicated —
        existing nodes are reused (resident OR host) and the caller's
        duplicate page simply stays slot-owned. Newly indexed pages get one
        cache reference (``allocator.incref``). Returns the number of pages
        newly retained.
        """
        tokens = [int(t) for t in tokens]
        ps = self.page_size
        need = -(-len(tokens) // ps) if tokens else 0
        if len(pages) < need:
            raise ValueError(f"insert needs {need} pages for "
                             f"{len(tokens)} tokens, got {len(pages)}")
        node = self._root(profile_key)
        added = 0
        for j in range(need):
            chunk = tuple(tokens[j * ps:(j + 1) * ps])
            full = len(chunk) == ps
            if full:
                child = node.children.get(chunk)
                if child is not None:
                    child.stamp = next(self._clock)
                    node = child
                    continue
            else:
                # final partial chunk: covered iff an existing child already
                # holds these tokens as a prefix
                if any(_common_prefix(c.tokens, chunk) == len(chunk)
                       for c in node.children.values()):
                    break
            page = int(pages[j])
            self.allocator.incref(page)
            child = _Node(chunk, page, node, next(self._clock))
            node.children[chunk] = child
            added += 1
            if not full:
                break
            node = child
        self.inserted_pages += added
        return added

    def insert_host(self, tokens: Sequence[int], handle: int,
                    profile_key: Optional[str] = None) -> bool:
        """Create ONE node directly in the host state (snapshot restore).

        ``tokens`` is the full token path from the root through the node's
        own chunk; every ancestor chunk must already exist (restore feeds
        entries parents-first). Returns False — without consuming the
        handle — when the node already exists or an ancestor is missing.
        """
        tokens = [int(t) for t in tokens]
        ps = self.page_size
        if not tokens:
            return False
        node = self._root(profile_key)
        n_chunks = -(-len(tokens) // ps)
        for j in range(n_chunks - 1):
            node = node.children.get(tuple(tokens[j * ps:(j + 1) * ps]))
            if node is None or node.count != ps:
                return False
        chunk = tuple(tokens[(n_chunks - 1) * ps:])
        if chunk in node.children:
            return False
        node.children[chunk] = _Node(chunk, -1, node, next(self._clock),
                                     host=handle)
        self.restored_pages += 1
        return True

    # -- snapshot -----------------------------------------------------------
    def iter_chain_nodes(self):
        """Yield ``(profile_key, full_tokens, node)`` for every cached page,
        parents before children — the snapshot serialization order."""
        for key, root in self._roots.items():
            stack = [(root, [])]
            while stack:
                node, prefix = stack.pop()
                if node.parent is not None:
                    prefix = prefix + list(node.tokens)
                    yield key, prefix, node
                for c in node.children.values():
                    stack.append((c, prefix))

    # -- eviction -----------------------------------------------------------
    def drop_host_lru(self) -> bool:
        """Destroy the LRU unpinned host-tier LEAF page (frees host room,
        no device effect). Returns False when none exists."""
        victim = None
        for n in self._all_nodes():
            if n.resident or n.host is None or n.pins or n.children:
                continue
            if victim is None or n.stamp < victim.stamp:
                victim = n
        if victim is None:
            return False
        self.pager.host.drop(victim.host)
        self._detach(victim)
        self.host_drops += 1
        self.tracer.instant("prefix.host_drop")
        return True

    def _drop_one(self) -> bool:
        """Destroy the LRU droppable RESIDENT leaf page (PR-3 eviction)."""
        victim = None
        for node in self._nodes():
            if node.children or node.pins:
                continue
            if self.allocator.refcount(node.page) != 1:
                continue
            if victim is None or node.stamp < victim.stamp:
                victim = node
        if victim is None:
            return False
        self._detach(victim)
        self.allocator.free([victim.page])
        self.evictions += 1
        self.tracer.instant("prefix.drop")
        return True

    def _demote_one(self) -> bool:
        """Spill the coldest (heat-scored) demotable resident page to the
        host tier (making host room first by dropping host LRU leaves if
        needed)."""
        if self.pager is None:
            return False
        cands = self._demotable_nodes()
        if not cands:
            return False
        while not self.pager.host.has_room(1):
            if not self.drop_host_lru():
                return False
        victim = min(cands, key=self._heat)
        victim.host = self.pager.demote(victim.page)
        victim.page = -1
        self.demotions += 1
        self.tracer.instant("prefix.demote")
        if self.demotions == 1:
            self.requants_at_first_demotion = self.requants
        return True

    def _requant_one(self) -> bool:
        """Requantize the coldest page one container step narrower and
        park it in the quant tier, freeing its device page WITHOUT a host
        round trip. The victim picker is heat-, age- and refcount-aware:
        lowest age+hit-count score (:meth:`_heat`) over resident refcount-1
        unpinned nodes (every resident page shares the pools' containers,
        so any candidate narrows equally). Returns False when no tier is
        attached, nothing can narrow, or the tier is out of byte room even
        after deepening already-parked pages."""
        if self.tier is None:
            return False
        cands = self._demotable_nodes()
        if not cands:
            return False
        victim = min(cands, key=self._heat)
        blob = self.tier.requantize(victim.page, valid_len=victim.count)
        if blob is None:
            return False
        while not self.tier.has_room(blob):
            if not self._deepen_one():
                return False
        handle = self.tier.put(blob)
        self.allocator.free([victim.page])
        victim.page = -1
        victim.tier = handle
        self.requants += 1
        self.tracer.instant("prefix.requant")
        return True

    def _deepen_one(self) -> bool:
        """Narrow the coldest (heat-scored) parked tier page one more
        container step (the fp -> int8 -> int4 progression under continued
        byte pressure). Returns False when no unpinned parked page can
        narrow further."""
        parked = sorted((n for n in self._all_nodes()
                         if n.tier is not None and not n.pins),
                        key=self._heat)
        for n in parked:
            if self.tier.deepen(n.tier, valid_len=n.count):
                self.deepens += 1
                self.tracer.instant("prefix.deepen")
                return True
        return False

    def evict(self, n_pages: int) -> int:
        """Release up to ``n_pages`` device pages held by the cache, in
        REQUANT -> DEMOTE -> DROP order: first requantize cold pages one
        container step narrower onto the on-device quant tier (lossy by
        the narrower grid's rounding, no host traffic), then DEMOTE to the
        host tier when a pager with room is attached (byte-exact, any
        chain position), and destroy LRU leaves only as the last resort.
        Returns the device pages actually freed."""
        freed = 0
        while freed < n_pages:
            if self._requant_one():
                freed += 1
                continue
            if self._demote_one():
                freed += 1
                continue
            if self._drop_one():
                freed += 1
                continue
            break
        return freed

    def clear(self) -> int:
        """Tear the cache down destructively: drop every unpinned,
        unreferenced page — resident, quant-tier AND host (leaf-first,
        cascading). Returns the number of device pages the cache STILL
        retains (pages some slot also references — nonzero after all slots
        released means a refcount leak)."""
        changed = True
        while changed:
            changed = False
            for node in self._all_nodes():
                if node.children or node.pins:
                    continue
                if node.resident:
                    if self.allocator.refcount(node.page) != 1:
                        continue
                    self._detach(node)
                    self.allocator.free([node.page])
                    self.evictions += 1
                elif node.tier is not None:
                    self.tier.drop(node.tier)
                    self._detach(node)
                else:
                    self.pager.host.drop(node.host)
                    self._detach(node)
                    self.host_drops += 1
                changed = True
        return self.num_pages

    def stats(self) -> dict:
        return {
            "lookups": self.lookups,
            "hits": self.hits,
            "hit_rate": self.hits / max(self.lookups, 1),
            "hit_tokens": self.hit_tokens,
            "token_hit_rate": self.hit_tokens / max(self.lookup_tokens, 1),
            "cached_pages": self.num_pages,
            "host_pages": self.host_pages,
            "evictable_pages": self.evictable_pages(),
            "inserted_pages": self.inserted_pages,
            "cow_copies": self.cow_copies,
            "evictions": self.evictions,
            "demotions": self.demotions,
            "promotions": self.promotions,
            "host_drops": self.host_drops,
            "restored_pages": self.restored_pages,
            "requants": self.requants,
            "deepens": self.deepens,
            "tier_pages": self.tier_pages,
            "tier_promotions": self.tier_promotions,
            "requantizable_pages": self.requantizable_pages(),
            "requants_at_first_demotion": self.requants_at_first_demotion,
        }
