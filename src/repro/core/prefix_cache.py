"""Shared-prefix page cache: a page-granular radix trie over prompt tokens.

Under real multi-user traffic most requests share long system-prompt
prefixes. Re-prefilling and re-storing those tokens per slot wastes both
compute (O(prompt/bucket) chunk forwards) and pool pages. This module keeps
a **radix/trie index over page-size token chunks**: each node is one pool
page whose KV holds the node's tokens at the node's absolute positions, so a
new request whose prompt starts with a cached chain can

* **alias** every fully-matched page (pure page-table indirection — the
  attention kernels never know; refcounts in
  :class:`repro.core.paged_kv.PageAllocator` keep aliased pages alive), and
* **copy-on-write** the page where it diverges mid-page: the matched prefix
  of the page is copied to a private page
  (:func:`repro.core.paged_kv.copy_pool_pages`) which the request then
  extends, while the cached source stays byte-identical for other readers.

Correctness invariants:

* only FULL pages are aliased — a sharer's first write position is always
  past every aliased page, so shared pages are never scattered to;
* partial nodes are leaves (a child chunk can only continue at the next
  page boundary, which requires its parent to be full);
* page content is position-dependent (RoPE is applied before the cache
  write), so a chain only ever matches prompts token-for-token from
  position 0 — exactly the lookup this trie implements;
* pages are only shared between identically-quantized configurations: the
  trie is namespaced by a **profile key** (the per-layer KV precision
  profile + scale mode), so an int8 chain can never back an int4 request.

Eviction is LRU over *unreferenced* cached pages (allocator refcount 1 —
held only by the cache), leaf-first so a chain never develops a hole. The
cache registers itself as the allocator's ``reclaim`` hook: pool pressure
evicts cold prefixes instead of failing the allocation.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from .paged_kv import PageAllocator

__all__ = ["PrefixCache", "PrefixHit"]


@dataclasses.dataclass
class PrefixHit:
    """Result of a longest-prefix lookup.

    ``matched == len(full_pages) * page_size + cow_valid``. ``full_pages``
    are aliasable as-is (every one is a full page); ``cow_page`` (if any) is
    the cached page the query diverges inside — the caller must copy it and
    may then treat its first ``cow_valid`` tokens as written.
    """

    matched: int = 0
    full_pages: List[int] = dataclasses.field(default_factory=list)
    cow_page: Optional[int] = None
    cow_valid: int = 0


class _Node:
    """One cached page: ``tokens`` (<= page_size) stored at ``page``.

    Children are keyed by their full token tuple for O(1) exact-chunk
    descent; partial children (count < page_size) are leaves and are found
    by the best-common-prefix scan.
    """

    __slots__ = ("tokens", "page", "children", "parent", "stamp")

    def __init__(self, tokens: Tuple[int, ...], page: int, parent, stamp: int):
        self.tokens = tokens
        self.page = page
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent = parent
        self.stamp = stamp

    @property
    def count(self) -> int:
        return len(self.tokens)


def _common_prefix(a: Sequence[int], b: Sequence[int]) -> int:
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


class PrefixCache:
    """Radix index of cached prompt pages over one server's page pool.

    The cache holds ONE allocator reference per cached page (taken at
    ``insert``), on top of whatever slots reference it — so a page is
    evictable exactly when its refcount is 1.
    """

    def __init__(self, allocator: PageAllocator, page_size: int,
                 profile_key: str = ""):
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.allocator = allocator
        self.page_size = page_size
        self.profile_key = profile_key
        self._roots: Dict[str, _Node] = {}
        self._clock = itertools.count()
        # instrumentation (benchmarks/serve read these)
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.lookup_tokens = 0
        self.inserted_pages = 0
        self.cow_copies = 0          # bumped by the server after each copy
        self.evictions = 0

    # -- internals ----------------------------------------------------------
    def _root(self, profile_key: Optional[str]) -> _Node:
        key = self.profile_key if profile_key is None else profile_key
        if key not in self._roots:
            self._roots[key] = _Node((), -1, None, next(self._clock))
        return self._roots[key]

    def _nodes(self) -> List[_Node]:
        out = []
        stack = list(self._roots.values())
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n.page >= 0:
                out.append(n)
        return out

    # -- stats --------------------------------------------------------------
    @property
    def num_pages(self) -> int:
        """Pages currently retained by the cache."""
        return len(self._nodes())

    def evictable_pages(self) -> int:
        """Pages reclaimable right now: refcount-1 nodes whose whole subtree
        is refcount-1 (an ancestor of a referenced page must stay, or the
        chain develops a hole while a reader still aliases the child)."""

        def count(node: _Node) -> Tuple[int, bool]:
            n, free = 0, True
            for c in node.children.values():
                cn, cfree = count(c)
                n += cn
                free &= cfree
            if node.page >= 0:
                if free and self.allocator.refcount(node.page) == 1:
                    return n + 1, True
                return n, False
            return n, free
        return sum(count(r)[0] for r in self._roots.values())

    # -- lookup -------------------------------------------------------------
    def lookup(self, tokens: Sequence[int],
               profile_key: Optional[str] = None,
               record: bool = True) -> PrefixHit:
        """Longest cached prefix of ``tokens`` (page-granular + intra-page).

        Pure read: no refcounts change. The caller pins (increfs) the hit's
        pages before any operation that could evict — lookup and pinning are
        adjacent, synchronous host work in the serving loop.

        ``record=False`` leaves the hit-rate counters untouched (the server
        passes it during admission, which may retry the same request every
        decode span while deferred, and records once on success via
        :meth:`note_lookup`); chain LRU stamps are refreshed either way.
        """
        tokens = [int(t) for t in tokens]
        if record:
            self.lookups += 1
            self.lookup_tokens += len(tokens)
        hit = PrefixHit()
        node = self._root(profile_key)
        ps = self.page_size
        i = 0
        while i < len(tokens):
            chunk = tuple(tokens[i:i + ps])
            child = node.children.get(chunk) if len(chunk) == ps else None
            if child is not None and child.count == ps:
                child.stamp = next(self._clock)
                hit.full_pages.append(child.page)
                hit.matched += ps
                node = child
                i += ps
                continue
            # diverging (or final sub-page) chunk: best intra-page match
            best, best_len = None, 0
            for c in node.children.values():
                n = _common_prefix(c.tokens, chunk)
                if n > best_len:
                    best, best_len = c, n
            if best is not None:
                best.stamp = next(self._clock)
                hit.cow_page = best.page
                hit.cow_valid = best_len
                hit.matched += best_len
            break
        if record and hit.matched:
            self.hits += 1
            self.hit_tokens += hit.matched
        return hit

    def note_lookup(self, n_tokens: int, matched: int) -> None:
        """Record one admission's hit-rate sample (pairs with
        ``lookup(record=False)``: counted once per ADMITTED request, not
        once per deferral retry)."""
        self.lookups += 1
        self.lookup_tokens += n_tokens
        if matched:
            self.hits += 1
            self.hit_tokens += matched

    # -- insert -------------------------------------------------------------
    def insert(self, tokens: Sequence[int], pages: Sequence[int],
               profile_key: Optional[str] = None) -> int:
        """Index ``tokens`` (page-chunked into ``pages``) into the trie.

        ``pages[j]`` must hold the KV of ``tokens[j*ps:(j+1)*ps]`` at those
        absolute positions (the caller's prefill just wrote them, or they
        came from this cache). Chunks already cached are deduplicated —
        existing nodes are reused and the caller's duplicate page simply
        stays slot-owned. Newly indexed pages get one cache reference
        (``allocator.incref``). Returns the number of pages newly retained.
        """
        tokens = [int(t) for t in tokens]
        ps = self.page_size
        need = -(-len(tokens) // ps) if tokens else 0
        if len(pages) < need:
            raise ValueError(f"insert needs {need} pages for "
                             f"{len(tokens)} tokens, got {len(pages)}")
        node = self._root(profile_key)
        added = 0
        for j in range(need):
            chunk = tuple(tokens[j * ps:(j + 1) * ps])
            full = len(chunk) == ps
            if full:
                child = node.children.get(chunk)
                if child is not None:
                    child.stamp = next(self._clock)
                    node = child
                    continue
            else:
                # final partial chunk: covered iff an existing child already
                # holds these tokens as a prefix
                if any(_common_prefix(c.tokens, chunk) == len(chunk)
                       for c in node.children.values()):
                    break
            page = int(pages[j])
            self.allocator.incref(page)
            child = _Node(chunk, page, node, next(self._clock))
            node.children[chunk] = child
            added += 1
            if not full:
                break
            node = child
        self.inserted_pages += added
        return added

    # -- eviction -----------------------------------------------------------
    def evict(self, n_pages: int) -> int:
        """Release up to ``n_pages`` LRU unreferenced cached pages.

        Leaf-first: only nodes with no children are candidates, so chains
        never develop holes; a parent becomes a candidate once its children
        are gone. Returns the number of pages actually freed."""
        freed = 0
        while freed < n_pages:
            victim = None
            for node in self._nodes():
                if node.children:
                    continue
                if self.allocator.refcount(node.page) != 1:
                    continue
                if victim is None or node.stamp < victim.stamp:
                    victim = node
            if victim is None:
                break
            del victim.parent.children[victim.tokens]
            self.allocator.free([victim.page])
            self.evictions += 1
            freed += 1
        return freed

    def clear(self) -> int:
        """Evict everything evictable; returns the number of pages the cache
        STILL retains (pages some slot also references — nonzero after all
        slots released means a refcount leak)."""
        self.evict(len(self._nodes()))
        return self.num_pages

    def stats(self) -> dict:
        return {
            "lookups": self.lookups,
            "hits": self.hits,
            "hit_rate": self.hits / max(self.lookups, 1),
            "hit_tokens": self.hit_tokens,
            "token_hit_rate": self.hit_tokens / max(self.lookup_tokens, 1),
            "cached_pages": self.num_pages,
            "evictable_pages": self.evictable_pages(),
            "inserted_pages": self.inserted_pages,
            "cow_copies": self.cow_copies,
            "evictions": self.evictions,
        }
