"""Per-layer precision assignment search (paper §2.5).

The paper's algorithm ("slowest gradient descent"):

  1. initialize all layers to a uniform precision with <0.1% error,
  2. form all delta configurations (each (layer, field) decremented by 1 bit),
  3. evaluate each, keep the delta with the best accuracy, iterate.

The trajectory of accepted configurations approximates the Pareto frontier in
(accuracy, traffic) space; for an error tolerance t, report the minimum-traffic
visited configuration with relative accuracy loss <= t (Table 2).

Beyond-paper: ``sensitivity_search`` replaces the O(L * bits * L) evaluation
count with a one-shot per-(layer, field) sensitivity profile followed by
largest-traffic-win-first greedy descent with accuracy backtracking — the same
frontier at a fraction of the evaluations; essential when one evaluation is a
full validation pass on a large model.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .policy import FIELDS, PrecisionPolicy
from .traffic import TrafficModel

EvalFn = Callable[[PrecisionPolicy], float]  # policy -> accuracy in [0, 1]


@dataclasses.dataclass
class SearchPoint:
    policy: PrecisionPolicy
    accuracy: float
    traffic_ratio: float
    move: Optional[Tuple[int, str]]  # (layer idx, field) that produced it

    def as_dict(self):
        return {
            "accuracy": self.accuracy,
            "traffic_ratio": self.traffic_ratio,
            "move": list(self.move) if self.move else None,
            "policy": json.loads(self.policy.to_json()),
        }


@dataclasses.dataclass
class SearchResult:
    baseline_accuracy: float
    trajectory: List[SearchPoint]
    evaluations: int
    wall_seconds: float

    def pareto(self) -> List[SearchPoint]:
        """Non-dominated points: no other point has >= acc and <= traffic."""
        pts = sorted(self.trajectory, key=lambda p: p.traffic_ratio)
        out, best_acc = [], -np.inf
        for p in pts:
            if p.accuracy > best_acc:
                out.append(p)
                best_acc = p.accuracy
        return out

    def select(self, tolerance: float) -> Optional[SearchPoint]:
        """Min-traffic config with relative accuracy loss <= tolerance."""
        ok = [p for p in self.trajectory
              if p.accuracy >= self.baseline_accuracy * (1.0 - tolerance)]
        if not ok:
            return None
        return min(ok, key=lambda p: p.traffic_ratio)

    def table(self, tolerances=(0.01, 0.02, 0.05, 0.10)) -> str:
        rows = ["tol    TR      acc     bits-per-layer (W data)", "-" * 64]
        for t in tolerances:
            p = self.select(t)
            if p is None:
                rows.append(f"{t:<6.0%} (none reachable)")
                continue
            bits = "-".join(
                f"{lp.weight.total_bits if lp.weight else 32}."
                f"{lp.data.total_bits if lp.data else 32}"
                for lp in p.policy.layers)
            rows.append(f"{t:<6.0%} {p.traffic_ratio:<7.3f} {p.accuracy:<7.4f} {bits}")
        return "\n".join(rows)

    def as_dict(self):
        return {
            "baseline_accuracy": self.baseline_accuracy,
            "evaluations": self.evaluations,
            "wall_seconds": self.wall_seconds,
            "trajectory": [p.as_dict() for p in self.trajectory],
        }


def greedy_pareto_search(eval_fn: EvalFn,
                         traffic: TrafficModel,
                         init: PrecisionPolicy,
                         *,
                         baseline_accuracy: Optional[float] = None,
                         fields: Sequence[str] = FIELDS,
                         batch_size: int = 1,
                         mode: str = "batch",
                         max_steps: int = 200,
                         stop_rel_acc: float = 0.25,
                         verbose: bool = False) -> SearchResult:
    """The paper's algorithm, §2.5 steps 1-3.

    ``stop_rel_acc``: abandon the descent once accuracy falls this far below
    baseline (the paper notes curves "drop off sharply" past ~10%).
    """
    t0 = time.time()
    if baseline_accuracy is None:
        baseline_accuracy = eval_fn(PrecisionPolicy.fp32_baseline(init.names))
    evals = 0

    cur = init
    cur_acc = eval_fn(cur)
    evals += 1
    traj = [SearchPoint(cur, cur_acc,
                        traffic.traffic_ratio(cur, batch_size, mode), None)]

    for step in range(max_steps):
        moves = cur.candidate_moves(fields)
        if not moves:
            break
        best = None
        for (mv, pol) in moves:
            acc = eval_fn(pol)
            evals += 1
            if best is None or acc > best[1]:
                best = (mv, acc, pol)
        mv, acc, pol = best
        cur, cur_acc = pol, acc
        traj.append(SearchPoint(cur, cur_acc,
                                traffic.traffic_ratio(cur, batch_size, mode), mv))
        if verbose:
            print(f"[search] step={step} move={mv} acc={acc:.4f} "
                  f"tr={traj[-1].traffic_ratio:.3f}")
        if cur_acc < baseline_accuracy * (1.0 - stop_rel_acc):
            break
    return SearchResult(baseline_accuracy, traj, evals, time.time() - t0)


def sensitivity_profile(eval_fn: EvalFn, init: PrecisionPolicy,
                        *, fields: Sequence[str] = FIELDS,
                        probe_bits: int = 2) -> Dict[Tuple[int, str], float]:
    """Beyond-paper: one evaluation per (layer, field) at an aggressively
    reduced probe precision; the accuracy drop ranks sensitivity."""
    out = {}
    for i in range(len(init)):
        for f in fields:
            cur = init.layers[i].get_field(f)
            if cur is None:
                continue
            floor = 1 if f.endswith("_int") else 0
            probe = max(floor, cur - probe_bits)
            if probe == cur:
                continue
            out[(i, f)] = eval_fn(init.with_field(i, f, probe))
    return out


def sensitivity_search(eval_fn: EvalFn,
                       traffic: TrafficModel,
                       init: PrecisionPolicy,
                       *,
                       baseline_accuracy: Optional[float] = None,
                       fields: Sequence[str] = FIELDS,
                       batch_size: int = 1,
                       mode: str = "batch",
                       tolerance: float = 0.10,
                       max_steps: int = 400,
                       verbose: bool = False) -> SearchResult:
    """Beyond-paper search: profile once, then decrement least-sensitive /
    highest-traffic-win fields first, backtracking on tolerance violation.

    Evaluations: O(L) profile + O(accepted moves), vs the paper's
    O(L * total_bits_removed) — typically 5-20x fewer model evaluations.
    """
    t0 = time.time()
    if baseline_accuracy is None:
        baseline_accuracy = eval_fn(PrecisionPolicy.fp32_baseline(init.names))
    evals = 0

    prof = sensitivity_profile(eval_fn, init, fields=fields)
    evals += len(prof)

    cur = init
    cur_acc = eval_fn(cur)
    evals += 1
    traj = [SearchPoint(cur, cur_acc,
                        traffic.traffic_ratio(cur, batch_size, mode), None)]
    floor_acc = baseline_accuracy * (1.0 - tolerance)
    frozen = set()

    for step in range(max_steps):
        # rank candidate moves: prefer high sensitivity score (= small drop)
        # breaking ties by traffic saved
        cands = []
        for (mv, pol) in cur.candidate_moves(fields):
            if mv in frozen:
                continue
            sens = prof.get(mv, cur_acc)
            saved = (traj[-1].traffic_ratio
                     - traffic.traffic_ratio(pol, batch_size, mode))
            cands.append((sens, saved, mv, pol))
        if not cands:
            break
        cands.sort(key=lambda c: (-c[0], -c[1]))
        sens, saved, mv, pol = cands[0]
        acc = eval_fn(pol)
        evals += 1
        prof[mv] = acc  # refresh the profile so ranking adapts as we descend
        if acc >= floor_acc:
            cur, cur_acc = pol, acc
            traj.append(SearchPoint(cur, cur_acc,
                                    traffic.traffic_ratio(cur, batch_size, mode),
                                    mv))
            if verbose:
                print(f"[sens-search] step={step} move={mv} acc={acc:.4f} "
                      f"tr={traj[-1].traffic_ratio:.3f}")
        else:
            frozen.add(mv)  # this field is at its floor for this tolerance
    return SearchResult(baseline_accuracy, traj, evals, time.time() - t0)
