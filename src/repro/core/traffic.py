"""Memory-traffic accounting AND open-loop serving-traffic generation.

Two traffic models live here:

1. **Per-layer byte traffic** (paper §2.4, Fig. 4, TR column of Table 2).
   The paper counts each datum as transferred once per layer execution
   (infinite on-chip reuse), and prices it at that layer's bit width:

       traffic_bits = sum_layers  accesses(layer, field) * bits(layer, field)

   Two use cases (paper Fig. 4): ``single`` — weights are re-read per
   image; ``batch`` — weights read once per layer per batch. TR (traffic
   ratio) is reported against a 32-bit-everywhere baseline. For the
   transformer archs the same model prices weight bytes, boundary
   activation bytes, and KV/state bytes per token — see ``quant.apply``.

2. **Open-loop request arrival traces** for the serving stack: seeded
   Poisson or bursty (2-state Markov-modulated Poisson) arrivals,
   heavy-tailed (lognormal, optionally Zipf-bucketed) prompt/output
   lengths, and multi-tenant mixes with per-tenant priority, deadline
   slack, and shared-prefix pools. ``generate_trace(TraceConfig)``
   returns a :class:`Trace` of :class:`TraceRequest` records —
   fully determined by the config + seed (``trace_fingerprint`` hashes
   the stream; determinism is subprocess-asserted in
   tests/test_traffic.py). The records are plain data so core stays
   import-clean of the launch layer; ``benchmarks/traffic.py`` converts
   them to ``launch.serve.Request`` objects for replay.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional, Sequence, Tuple

import numpy as np

from .policy import PrecisionPolicy

BASELINE_BITS = 32


@dataclasses.dataclass(frozen=True)
class LayerTraffic:
    """Access counts for one layer, in element units (not bytes)."""

    name: str
    weight_elems: int      # model parameters touched by the layer
    data_in_elems: int     # activations read (per image / per sequence)
    data_out_elems: int    # activations written

    @property
    def data_elems(self) -> int:
        return self.data_in_elems + self.data_out_elems


@dataclasses.dataclass(frozen=True)
class TrafficModel:
    layers: tuple  # tuple[LayerTraffic]

    @property
    def names(self):
        return tuple(l.name for l in self.layers)

    # -- raw access counts (paper Fig. 4) -------------------------------------
    def accesses(self, batch_size: int = 1, mode: str = "batch"):
        """Returns (weight_accesses, data_accesses) summed over layers."""
        w = sum(l.weight_elems for l in self.layers)
        d = sum(l.data_elems for l in self.layers) * batch_size
        if mode == "single":
            w = w * batch_size  # weights re-read for every image
        elif mode != "batch":
            raise ValueError(mode)
        return w, d

    # -- priced traffic ---------------------------------------------------------
    def traffic_bits(self, policy: PrecisionPolicy, batch_size: int = 1,
                     mode: str = "batch") -> float:
        assert policy.names == self.names, "policy/traffic layer mismatch"
        total = 0.0
        for lt, lp in zip(self.layers, policy.layers):
            wbits = lp.weight.total_bits if lp.weight else BASELINE_BITS
            dbits = lp.data.total_bits if lp.data else BASELINE_BITS
            w = lt.weight_elems * (batch_size if mode == "single" else 1)
            total += w * wbits + lt.data_elems * batch_size * dbits
        return total

    def baseline_bits(self, batch_size: int = 1, mode: str = "batch") -> float:
        w, d = self.accesses(batch_size, mode)
        return (w + d) * BASELINE_BITS

    def traffic_ratio(self, policy: PrecisionPolicy, batch_size: int = 1,
                      mode: str = "batch") -> float:
        """TR: priced traffic / 32-bit baseline (paper Table 2)."""
        return (self.traffic_bits(policy, batch_size, mode)
                / self.baseline_bits(batch_size, mode))

    def footprint_bytes(self, policy: PrecisionPolicy) -> float:
        """Static storage: weights once + one live copy of boundary data."""
        total = 0.0
        for lt, lp in zip(self.layers, policy.layers):
            wbits = lp.weight.total_bits if lp.weight else BASELINE_BITS
            dbits = lp.data.total_bits if lp.data else BASELINE_BITS
            total += (lt.weight_elems * wbits + lt.data_out_elems * dbits) / 8.0
        return total


# ---------------------------------------------------------------------------
# Open-loop arrival-trace generation (serving traffic)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant class in a traffic mix.

    Lengths are lognormal (heavy-tailed): ``prompt_mean``/``max_new_mean``
    are the distribution MEANS in tokens (the underlying mu is derived),
    clipped to ``[1, *_cap]``. ``deadline_slack`` prices the SLO on the
    decode-step clock: an arrival at step t with n output tokens gets
    ``deadline_step = t + n + slack`` (slack = queueing budget; ``None``
    = no deadline, i.e. throughput/batch traffic that counts toward
    goodput whenever it finishes). ``shared_prefix_len > 0`` draws one of
    ``prefix_pool`` per-tenant system prompts (Zipf-weighted so pool entry
    0 is hottest) and prepends it — the knob that exercises the
    shared-prefix cache and host-tier promotions under a trace.
    """

    name: str
    weight: float = 1.0
    priority: int = 0
    deadline_slack: Optional[int] = None
    prompt_mean: float = 12.0
    prompt_sigma: float = 0.6
    prompt_cap: int = 48
    max_new_mean: float = 8.0
    max_new_sigma: float = 0.5
    max_new_cap: int = 32
    shared_prefix_len: int = 0
    prefix_pool: int = 1
    zipf_a: float = 1.5


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Seeded open-loop arrival process over a horizon of decode steps.

    ``process="poisson"`` draws ``Poisson(rate)`` arrivals per step.
    ``process="bursty"`` is a 2-state MMPP: a Markov chain flips between
    a quiet state (``rate``) and a burst state (``burst_rate``) with
    per-step entry/exit probabilities — arrivals cluster, which is what
    saturates an SLO scheduler (mean offered load can be modest while
    the instantaneous burst load is >> sustainable throughput).
    """

    seed: int = 0
    horizon: int = 64
    rate: float = 0.25
    process: str = "poisson"
    burst_rate: float = 1.0
    p_enter_burst: float = 0.05
    p_exit_burst: float = 0.25
    vocab_size: int = 1000
    tenants: Tuple[TenantSpec, ...] = (TenantSpec("default"),)


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One generated arrival — plain data, convertible to a serve Request."""

    rid: int
    tenant: str
    arrive_step: int
    prompt: np.ndarray          # int32 tokens (shared prefix + fresh tail)
    max_new: int
    priority: int
    deadline_step: Optional[int]
    prefix_id: int              # index into the tenant's prefix pool (-1: none)


@dataclasses.dataclass(frozen=True)
class Trace:
    config: TraceConfig
    requests: Tuple[TraceRequest, ...]
    burst_steps: Tuple[int, ...]     # steps the MMPP spent in the burst state

    @property
    def offered_rate(self) -> float:
        """Mean arrivals per decode step over the horizon."""
        return len(self.requests) / max(1, self.config.horizon)

    def burst_rate_observed(self) -> float:
        """Arrivals per step measured over burst-state steps only."""
        if not self.burst_steps:
            return self.offered_rate
        burst = set(self.burst_steps)
        n = sum(1 for r in self.requests if r.arrive_step in burst)
        return n / len(burst)

    def mean_max_new(self) -> float:
        if not self.requests:
            return 0.0
        return float(np.mean([r.max_new for r in self.requests]))

    def overload_ratio(self, batch_size: int) -> float:
        """Burst-state offered load vs sustainable throughput.

        Sustainable decode throughput is ``batch_size / mean_service``
        requests per step (each live row emits one token per step), so
        the ratio > 1 means the burst arrives faster than the server can
        possibly drain it — the regime where admission policy, not raw
        speed, decides goodput.
        """
        service = self.mean_max_new()
        if service <= 0:
            return 0.0
        return self.burst_rate_observed() * service / max(1, batch_size)


def _lognormal_len(rng: np.random.Generator, mean: float, sigma: float,
                   cap: int) -> int:
    # parameterize by the distribution mean: mu = ln(mean) - sigma^2/2
    mu = np.log(max(1.0, mean)) - 0.5 * sigma * sigma
    return int(np.clip(round(rng.lognormal(mu, sigma)), 1, max(1, cap)))


def _zipf_pick(rng: np.random.Generator, n: int, a: float) -> int:
    if n <= 1:
        return 0
    w = 1.0 / np.arange(1, n + 1) ** a
    return int(rng.choice(n, p=w / w.sum()))


def generate_trace(cfg: TraceConfig) -> Trace:
    """Deterministically expand a TraceConfig into arrival records.

    All randomness flows from one ``np.random.default_rng(cfg.seed)``
    in a fixed draw order, so equal configs yield identical traces
    across processes and platforms.
    """
    if cfg.process not in ("poisson", "bursty"):
        raise ValueError(f"unknown arrival process: {cfg.process!r}")
    if not cfg.tenants:
        raise ValueError("TraceConfig needs at least one tenant")
    rng = np.random.default_rng(cfg.seed)
    weights = np.asarray([t.weight for t in cfg.tenants], dtype=np.float64)
    if weights.sum() <= 0:
        raise ValueError("tenant weights must sum > 0")
    weights = weights / weights.sum()

    # per-(tenant, pool slot) shared prefixes, drawn up front so tenant
    # order — not arrival order — determines their token content
    prefixes = {}
    for t in cfg.tenants:
        if t.shared_prefix_len > 0:
            for p in range(max(1, t.prefix_pool)):
                prefixes[(t.name, p)] = rng.integers(
                    0, cfg.vocab_size, t.shared_prefix_len).astype(np.int32)

    requests = []
    burst_steps = []
    in_burst = False
    rid = 0
    for step in range(cfg.horizon):
        if cfg.process == "bursty":
            flip = rng.random()
            in_burst = ((not in_burst and flip < cfg.p_enter_burst)
                        or (in_burst and flip >= cfg.p_exit_burst))
            if in_burst:
                burst_steps.append(step)
        rate = cfg.burst_rate if in_burst else cfg.rate
        for _ in range(int(rng.poisson(rate))):
            t = cfg.tenants[int(rng.choice(len(cfg.tenants), p=weights))]
            n_prompt = _lognormal_len(rng, t.prompt_mean, t.prompt_sigma,
                                      t.prompt_cap)
            max_new = _lognormal_len(rng, t.max_new_mean, t.max_new_sigma,
                                     t.max_new_cap)
            prefix_id = -1
            parts = []
            if t.shared_prefix_len > 0:
                prefix_id = _zipf_pick(rng, max(1, t.prefix_pool), t.zipf_a)
                parts.append(prefixes[(t.name, prefix_id)])
            parts.append(rng.integers(0, cfg.vocab_size, n_prompt)
                         .astype(np.int32))
            deadline = (None if t.deadline_slack is None
                        else step + max_new + t.deadline_slack)
            requests.append(TraceRequest(
                rid=rid, tenant=t.name, arrive_step=step,
                prompt=np.concatenate(parts), max_new=max_new,
                priority=t.priority, deadline_step=deadline,
                prefix_id=prefix_id))
            rid += 1
    return Trace(config=cfg, requests=tuple(requests),
                 burst_steps=tuple(burst_steps))


def trace_fingerprint(trace: Trace) -> str:
    """sha256 over the full arrival/length/tenant/token stream."""
    h = hashlib.sha256()
    for r in trace.requests:
        h.update(f"{r.rid}|{r.tenant}|{r.arrive_step}|{r.max_new}|"
                 f"{r.priority}|{r.deadline_step}|{r.prefix_id}|".encode())
        h.update(np.ascontiguousarray(r.prompt, dtype=np.int32).tobytes())
    return h.hexdigest()
