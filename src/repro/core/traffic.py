"""Memory-traffic accounting (paper §2.4, Fig. 4, and the TR column of Table 2).

The paper counts each datum as transferred once per layer execution (infinite
on-chip reuse), and prices it at that layer's bit width:

    traffic_bits = sum_layers  accesses(layer, field) * bits(layer, field)

Two use cases (paper Fig. 4): ``single`` — weights are re-read per image;
``batch`` — weights read once per layer per batch. TR (traffic ratio) is
reported against a 32-bit-everywhere baseline.

For the transformer archs the same model prices weight bytes, boundary
activation bytes, and KV/state bytes per token — see ``quant.apply`` for how
layer access counts are extracted from a model config.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from .policy import PrecisionPolicy

BASELINE_BITS = 32


@dataclasses.dataclass(frozen=True)
class LayerTraffic:
    """Access counts for one layer, in element units (not bytes)."""

    name: str
    weight_elems: int      # model parameters touched by the layer
    data_in_elems: int     # activations read (per image / per sequence)
    data_out_elems: int    # activations written

    @property
    def data_elems(self) -> int:
        return self.data_in_elems + self.data_out_elems


@dataclasses.dataclass(frozen=True)
class TrafficModel:
    layers: tuple  # tuple[LayerTraffic]

    @property
    def names(self):
        return tuple(l.name for l in self.layers)

    # -- raw access counts (paper Fig. 4) -------------------------------------
    def accesses(self, batch_size: int = 1, mode: str = "batch"):
        """Returns (weight_accesses, data_accesses) summed over layers."""
        w = sum(l.weight_elems for l in self.layers)
        d = sum(l.data_elems for l in self.layers) * batch_size
        if mode == "single":
            w = w * batch_size  # weights re-read for every image
        elif mode != "batch":
            raise ValueError(mode)
        return w, d

    # -- priced traffic ---------------------------------------------------------
    def traffic_bits(self, policy: PrecisionPolicy, batch_size: int = 1,
                     mode: str = "batch") -> float:
        assert policy.names == self.names, "policy/traffic layer mismatch"
        total = 0.0
        for lt, lp in zip(self.layers, policy.layers):
            wbits = lp.weight.total_bits if lp.weight else BASELINE_BITS
            dbits = lp.data.total_bits if lp.data else BASELINE_BITS
            w = lt.weight_elems * (batch_size if mode == "single" else 1)
            total += w * wbits + lt.data_elems * batch_size * dbits
        return total

    def baseline_bits(self, batch_size: int = 1, mode: str = "batch") -> float:
        w, d = self.accesses(batch_size, mode)
        return (w + d) * BASELINE_BITS

    def traffic_ratio(self, policy: PrecisionPolicy, batch_size: int = 1,
                      mode: str = "batch") -> float:
        """TR: priced traffic / 32-bit baseline (paper Table 2)."""
        return (self.traffic_bits(policy, batch_size, mode)
                / self.baseline_bits(batch_size, mode))

    def footprint_bytes(self, policy: PrecisionPolicy) -> float:
        """Static storage: weights once + one live copy of boundary data."""
        total = 0.0
        for lt, lp in zip(self.layers, policy.layers):
            wbits = lp.weight.total_bits if lp.weight else BASELINE_BITS
            dbits = lp.data.total_bits if lp.data else BASELINE_BITS
            total += (lt.weight_elems * wbits + lt.data_out_elems * dbits) / 8.0
        return total
