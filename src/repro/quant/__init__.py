from .apply import (build_model_quant, transformer_layer_names,
                    transformer_traffic_model, quantize_param_tree,
                    policy_footprint_report)

__all__ = ["build_model_quant", "transformer_layer_names",
           "transformer_traffic_model", "quantize_param_tree",
           "policy_footprint_report"]
