"""Glue between the paper's PrecisionPolicy and the transformer stack.

* ``transformer_layer_names`` — the policy's layer-name space for an arch.
* ``build_model_quant`` — policy -> ModelQuant (stacked (L,) Q(I,F) arrays
  that ride the scan; weights, residual data, and KV/state bits).
* ``transformer_traffic_model`` — the paper's §2.4 access counting applied to
  a transformer workload (train / prefill / decode), so the §2.5 search can
  optimize real LLM traffic.
* ``quantize_param_tree`` — pack a trained param tree into QuantizedTensors
  (real checkpoint footprint reduction, not just fake-quant).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.policy import PrecisionPolicy
from ..core.qtensor import QuantizedTensor
from ..core.traffic import LayerTraffic, TrafficModel
from ..configs.counting import kv_bytes_per_token, layer_param_count
from ..models.transformer import ModelQuant


def transformer_layer_names(cfg) -> Tuple[str, ...]:
    return tuple(f"layer_{i:03d}" for i in range(cfg.num_layers))


def build_model_quant(policy: Optional[PrecisionPolicy], cfg,
                      *, quantize_kv: bool = True,
                      quantize_activations: bool = True,
                      kv_container: str = "int8",
                      per_layer_kv: bool = False,
                      kv_scale_mode: str = "static",
                      kv_unroll: bool = False) -> Optional[ModelQuant]:
    """PrecisionPolicy -> ModelQuant. Policy layer i == transformer layer i.

    The KV/state cache inherits each layer's *data* format (the cache IS the
    layer's inter-step data), clipped to the container width.
    ``quantize_activations=False`` restricts the data bits to the cache only
    (KV-quantized serving without residual-stream fake-quant).

    ``per_layer_kv=True`` derives a **per-layer storage container** from
    each layer's data bits (<= 4 total bits -> lane-packed "int4", <= 8 ->
    "int8", an fp32 layer -> "fp" float pages) instead of one uniform
    container — the serving path that lets a ``core.search`` policy drive
    the at-rest KV footprint. Paged caches only (see
    ``models.transformer.init_cache``). Contiguous same-container layer
    runs ride ``lax.scan``; ``kv_unroll=True`` forces the fully unrolled
    reference path (identity tests / debugging).
    """
    if policy is None:
        return None
    assert len(policy) == cfg.num_layers, \
        f"policy has {len(policy)} layers, model has {cfg.num_layers}"
    w_i, w_f, w_en = policy.stacked_arrays("weight")
    a_i, a_f, a_en = policy.stacked_arrays("data")
    kv_i = kv_f = None
    kv_containers = None
    if quantize_kv and per_layer_kv:
        kv_containers = tuple(kv_layer_container(lp.data)
                              for lp in policy.layers)
        caps = jnp.asarray([{"int4": 4, "int8": 8, "fp": 8}[c]
                            for c in kv_containers], jnp.float32)
        tot = jnp.clip(a_i + a_f, 2, caps)
        kv_i = jnp.minimum(a_i, tot - 1)
        kv_f = tot - kv_i
    elif quantize_kv:
        cap = {"int4": 4, "int8": 8, "int16": 16}[kv_container]
        tot = jnp.clip(a_i + a_f, 2, cap)
        kv_i = jnp.minimum(a_i, tot - 1)
        kv_f = tot - kv_i
    act_on = quantize_activations and bool(a_en.any())
    return ModelQuant(
        w_int=w_i if bool(w_en.any()) else None,
        w_frac=w_f if bool(w_en.any()) else None,
        a_int=a_i if act_on else None,
        a_frac=a_f if act_on else None,
        kv_int=kv_i, kv_frac=kv_f, kv_container=kv_container,
        kv_containers=kv_containers, kv_scale_mode=kv_scale_mode,
        kv_unroll=kv_unroll)


def kv_layer_container(data_fmt) -> str:
    """Storage container for one layer's KV under its data format."""
    if data_fmt is None:
        return "fp"
    return "int4" if data_fmt.total_bits <= 4 else "int8"


def kv_profile_key(policy: Optional[PrecisionPolicy], *,
                   kv_bits: int = 0, kv_scale_mode: str = "static") -> str:
    """Canonical string identifying a KV quantization configuration.

    The prefix cache namespaces its trie by this key, so pages are only
    ever shared between identically-quantized configurations — an int8
    chain can never back an int4 request, and a per-layer profile never
    aliases a uniform one unless they quantize every layer identically.
    """
    if policy is not None:
        per = ",".join(
            f"{kv_layer_container(lp.data)}"
            + (f":Q{lp.data.int_bits}.{lp.data.frac_bits}" if lp.data else "")
            for lp in policy.layers)
    else:
        per = f"uniform{kv_bits}"
    return f"{per}|scale={kv_scale_mode}"


def transformer_traffic_model(cfg, *, batch: int, seq_len: int,
                              mode: str = "train") -> TrafficModel:
    """Access counts per layer for the paper's traffic accounting.

    train/prefill: weights once per batch; data = residual in+out per token.
    decode: per generated token — weights once, KV history read once
    (the dominant term the paper's 'batch' analysis predicts).
    """
    from ..models.transformer import layer_signatures
    names = transformer_layer_names(cfg)
    sigs = layer_signatures(cfg)
    layers = []
    tok = batch * seq_len
    D = cfg.d_model
    for i, n in enumerate(names):
        w = layer_param_count(cfg, i, active_only=True)
        if mode in ("train", "prefill"):
            d_in = tok * D
            d_out = tok * D
        elif mode == "decode":
            kind, _ = sigs[i]
            kv_hist = 0
            if kind == "attn":
                kv_hist = (seq_len * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
                           if cfg.attention_type == "mla"
                           else seq_len * 2 * cfg.num_kv_heads * cfg.head_dim)
            d_in = batch * (D + kv_hist)
            d_out = batch * D
        else:
            raise ValueError(mode)
        layers.append(LayerTraffic(n, w, d_in, d_out))
    return TrafficModel(tuple(layers))


def quantize_param_tree(params, policy: PrecisionPolicy, cfg, *,
                        pack: bool = True):
    """Pack each segment's stacked weights into QuantizedTensors using the
    per-layer weight formats (bucketing by container is implicit: each layer's
    stacked leaf gets the max container among its layers' formats).

    Used by the quantized-checkpoint path; compute-side dequant happens in
    kernels/quant_matmul or via .dequantize().
    """
    from ..models.transformer import layer_segments

    def fmt_for(start, periods, npos):
        idx = [start + p * npos + j for p in range(periods) for j in range(npos)]
        fmts = [policy.layers[i].weight for i in idx]
        return fmts

    out = {"embed": params["embed"], "final_norm": params["final_norm"]}
    if "head" in params:
        out["head"] = params["head"]
    if "mtp" in params:
        out["mtp"] = params["mtp"]
    segs_q = []
    for (pattern, periods, start), seg in zip(layer_segments(cfg),
                                              params["segments"]):
        npos = len(pattern)
        fmts = fmt_for(start, periods, npos)
        ib = max((f.int_bits for f in fmts if f), default=2)
        fb = max((f.frac_bits for f in fmts if f), default=6)

        def q(leaf):
            if leaf.ndim >= 3 and jnp.issubdtype(leaf.dtype, jnp.floating):
                return QuantizedTensor.from_float(
                    leaf, ib, fb, pack=pack and (ib + fb) <= 8)
            return leaf
        segs_q.append(jax.tree_util.tree_map(q, seg))
    out["segments"] = segs_q
    return out


def policy_footprint_report(policy: PrecisionPolicy, cfg, *, batch: int,
                            seq_len: int) -> dict:
    """Bytes summary for EXPERIMENTS.md: weights / KV / residual data under
    the policy vs fp32 and 16-bit baselines."""
    tm = transformer_traffic_model(cfg, batch=batch, seq_len=seq_len,
                                   mode="decode")
    tr = tm.traffic_ratio(policy, batch_size=1)
    w_bits = [lp.weight.total_bits if lp.weight else 32
              for lp in policy.layers]
    d_bits = [lp.data.total_bits if lp.data else 32 for lp in policy.layers]
    return {
        "traffic_ratio_vs_fp32": tr,
        "traffic_ratio_vs_16b": tr * 2.0,
        "mean_weight_bits": float(np.mean(w_bits)),
        "mean_data_bits": float(np.mean(d_bits)),
        "kv_bytes_per_token_fp32": kv_bytes_per_token(cfg, 4.0),
        "kv_bytes_per_token_policy": kv_bytes_per_token(
            cfg, float(np.mean(d_bits)) / 8.0),
    }
