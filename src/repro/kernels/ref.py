"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Shared numerics with the core library where it matters: the fake-quant grid
definition is imported from core.fixedpoint, so kernel == library == paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.fixedpoint import fake_quant, format_params

NEG_INF = -1e30


def quant_cast_ref(x, int_bits: int, frac_bits: int):
    """Fake-quant Q(I,F): round-half-away, clip, rescale (paper §2.1)."""
    return fake_quant(x, int_bits, frac_bits)


# ---------------------------------------------------------------------------
# Lane packing: k N-bit fields per int32 word, little-endian in bit order.
# ---------------------------------------------------------------------------
def values_per_word(bits: int) -> int:
    assert bits in (2, 4, 8, 16), bits
    return 32 // bits


def pack_ref(q, bits: int):
    """q: (..., N) int32 integer-grid values in [-2^(bits-1), 2^(bits-1)-1].
    Returns (..., N // vpw) int32 packed words."""
    vpw = values_per_word(bits)
    assert q.shape[-1] % vpw == 0
    mask = jnp.uint32((1 << bits) - 1)
    qu = q.astype(jnp.uint32) & mask
    grp = qu.reshape(*q.shape[:-1], q.shape[-1] // vpw, vpw)
    shifts = (jnp.arange(vpw, dtype=jnp.uint32) * bits)
    word = jnp.bitwise_or.reduce(grp << shifts, axis=-1) \
        if hasattr(jnp.bitwise_or, "reduce") else None
    if word is None:
        word = jnp.zeros(grp.shape[:-1], jnp.uint32)
        for i in range(vpw):
            word = word | (grp[..., i] << jnp.uint32(i * bits))
    return jax.lax.bitcast_convert_type(word, jnp.int32)


def unpack_ref(w, bits: int):
    """Inverse of pack_ref (sign-extending). w: (..., M) int32 ->
    (..., M * vpw) int32."""
    vpw = values_per_word(bits)
    wu = jax.lax.bitcast_convert_type(w, jnp.uint32)
    shifts = (jnp.arange(vpw, dtype=jnp.uint32) * bits)
    mask = jnp.uint32((1 << bits) - 1)
    fields = (wu[..., None] >> shifts) & mask              # (..., M, vpw)
    sign = jnp.uint32(1 << (bits - 1))
    vals = (fields ^ sign).astype(jnp.int32) - jnp.int32(sign)
    return vals.reshape(*w.shape[:-1], w.shape[-1] * vpw)


# ---------------------------------------------------------------------------
# Quantized matmul: W stored on an int grid, per-output-channel scale.
# ---------------------------------------------------------------------------
def quant_matmul_ref(a, wq, scales):
    """a: (M, K) float; wq: (K, N) int8/int16 grid; scales: (N,) fp32.
    out (M, N) fp32 = a @ (wq * scales)."""
    af = a.astype(jnp.float32)
    wf = wq.astype(jnp.float32) * scales[None, :].astype(jnp.float32)
    return af @ wf


# ---------------------------------------------------------------------------
# Decode attention over an int8-quantized KV cache (per-layer Q(I,F)).
# ---------------------------------------------------------------------------
def masked_decode_attention_ref(q, k, v, kv_len):
    """Full-materialization decode attention. q: (B, H, hd); k/v:
    (B, T, KV, hd) float; kv_len: (B,) or scalar. Returns (B, H, hd) f32."""
    B, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32) / np.sqrt(hd)
    s = jnp.einsum("bkgh,btkh->bkgt", qg, k.astype(jnp.float32))
    lens = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (B,))
    mask = jnp.arange(T)[None, None, None, :] < lens[:, None, None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkh->bkgh", p, v.astype(jnp.float32))
    return o.reshape(B, H, hd)


def paged_kv_attention_ref(q, k_pages, v_pages, k_scale, v_scale, page_table,
                           kv_len, *, bits: int = 8, head_dim=None):
    """Oracle for the paged kernel: gather pages into the logical dense view,
    dequantize with the per-page scales, run masked softmax attention.

    Shapes as in ``paged_kv_attention_decode``; supports fragmented page
    tables and per-row kv_len (partial last pages are masked).
    """
    from ..core.paged_kv import paged_gather
    container = {0: "fp", 8: "int8", 4: "int4"}[bits]
    pool = {"k_pages": k_pages, "v_pages": v_pages,
            "k_scale": k_scale, "v_scale": v_scale}
    hd = head_dim if head_dim is not None else q.shape[-1]
    k, v = paged_gather(pool, jnp.asarray(page_table, jnp.int32),
                        container=container, head_dim=hd)
    return masked_decode_attention_ref(q, k, v, kv_len)


def make_fragmented_pool(rng, B, NP, ps, kv, hd, bits, extra_pages=3):
    """Shared oracle-test/bench fixture: a random quantized pool plus an
    OUT-OF-ORDER page table (non-scratch page ids shuffled across
    sequences) — the fragmentation the paged kernels must be invariant to.
    Returns ``(k_pages, v_pages, k_scale, v_scale, page_table)`` with the
    page table as a numpy (B, NP) int32 array (callers jnp.asarray as
    needed). ``bits``: 8 (int8 grid), 4 (lane-packed int32), 0 (float)."""
    from ..core.qtensor import pack_bits
    P = 1 + B * NP + extra_pages
    if bits == 8:
        kq = jnp.asarray(rng.integers(-128, 128, (P, ps, kv, hd)), jnp.int8)
        vq = jnp.asarray(rng.integers(-128, 128, (P, ps, kv, hd)), jnp.int8)
    elif bits == 4:
        kq, _ = pack_bits(jnp.asarray(rng.integers(-8, 8, (P, ps, kv, hd)),
                                      jnp.int32), 4)
        vq, _ = pack_bits(jnp.asarray(rng.integers(-8, 8, (P, ps, kv, hd)),
                                      jnp.int32), 4)
    else:
        kq = jnp.asarray(rng.normal(size=(P, ps, kv, hd)), jnp.float32)
        vq = jnp.asarray(rng.normal(size=(P, ps, kv, hd)), jnp.float32)
    ks = jnp.asarray(rng.uniform(0.005, 0.08, P), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.005, 0.08, P), jnp.float32)
    ids = np.arange(1, P)
    rng.shuffle(ids)
    pt = ids[:B * NP].reshape(B, NP).astype(np.int32)
    return kq, vq, ks, vs, pt


def paged_kv_attention_chunk_ref(q, k_pages, v_pages, k_scale, v_scale,
                                 page_table, q_start, kv_len, *,
                                 bits: int = 8, head_dim=None):
    """Oracle for the variable-length chunk kernel: gather pages into the
    logical dense view, dequantize with the per-page scales, run softmax
    attention with per-row causal masking against absolute query positions
    (``q_start[b] + i``) and the row's ``kv_len``.

    q: (B, S, H, hd); other shapes as in ``paged_kv_attention_chunk``.
    """
    from ..core.paged_kv import paged_gather
    container = {0: "fp", 8: "int8", 4: "int4"}[bits]
    pool = {"k_pages": k_pages, "v_pages": v_pages,
            "k_scale": k_scale, "v_scale": v_scale}
    B, S, H, _ = q.shape
    hd = head_dim if head_dim is not None else q.shape[-1]
    k, v = paged_gather(pool, jnp.asarray(page_table, jnp.int32),
                        container=container, head_dim=hd)
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qs = jnp.broadcast_to(jnp.asarray(q_start, jnp.int32).reshape(-1), (B,))
    lens = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (B,))
    qg = q.reshape(B, S, KV, G, hd).astype(jnp.float32) / np.sqrt(hd)
    s = jnp.einsum("bskgh,btkh->bkgst", qg, k.astype(jnp.float32))
    pos = jnp.arange(T)
    q_pos = qs[:, None] + jnp.arange(S)[None, :]            # (B, S)
    mask = (pos[None, None, :] <= q_pos[:, :, None]) & \
        (pos[None, None, :] < lens[:, None, None])
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd)


def kv_attention_ref(q, k_q, v_q, int_bits, frac_bits, kv_len):
    """q: (B, H, hd) float; k_q/v_q: (B, T, KV, hd) int8 grid; kv_len: int.
    GQA decode: one new token attends to the first kv_len cache entries.
    Returns (B, H, hd) float32."""
    scale, _, _ = format_params(int_bits, frac_bits)
    k = k_q.astype(jnp.float32) / scale
    v = v_q.astype(jnp.float32) / scale
    return masked_decode_attention_ref(q, k, v, kv_len)
