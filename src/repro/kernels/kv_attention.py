"""Pallas kernel: GQA decode attention over an int8-quantized KV cache.

The dominant decode traffic is the KV-history read (paper §2.4's "data
dominates batch processing", 2026 edition). This kernel reads the cache in
its Q(I,F) int8 container, dequantizes chunk-by-chunk in VMEM, and runs
online softmax — the cache never exists dequantized in HBM, so HBM bytes are
truly ~4x smaller than an fp32 cache (2x vs bf16).

Layout: q (B, KV, G, hd) fp32, cache (B, T, KV, hd) int8.
Grid (B, KV, T/bt), T innermost sequential; the (m, l, acc) online-softmax
state lives in VMEM scratch and carries across T steps. Tile sizes:
k/v (bt=512, hd=128) int8 = 64 KB each; hd=128 lanes MXU/VPU aligned.
kv_len rides in SMEM, masking the tail tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kv_attn_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                    m_ref, l_ref, acc_ref, *, nt, bt, kv_scale, sm_scale):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * sm_scale          # (G, hd)
    k = k_ref[0, :, 0].astype(jnp.float32) * kv_scale       # (bt, hd)
    v = v_ref[0, :, 0].astype(jnp.float32) * kv_scale       # (bt, hd)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (G, bt)
    pos = t * bt + jax.lax.broadcasted_iota(jnp.int32, (1, bt), 1)
    s = jnp.where(pos < len_ref[0], s, NEG_INF)

    m_prev = m_ref[...]                                      # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                                   # (G, bt)
    corr = jnp.exp(m_prev - m_new)                           # (G, 1)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + \
        jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(t == nt - 1)
    def _fin():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("int_bits", "frac_bits",
                                             "block_t", "interpret"))
def kv_attention_decode(q, k_q, v_q, kv_len, *, int_bits: int,
                        frac_bits: int, block_t: int = 512,
                        interpret: bool = False):
    """q: (B, H, hd) float; k_q/v_q: (B, T, KV, hd) int8 Q(I,F) grid;
    kv_len: scalar int32. Returns (B, H, hd) float32."""
    B, H, hd = q.shape
    T, KV = k_q.shape[1], k_q.shape[2]
    G = H // KV
    bt = min(block_t, T)
    pt = (-T) % bt
    if pt:
        k_q = jnp.pad(k_q, ((0, 0), (0, pt), (0, 0), (0, 0)))
        v_q = jnp.pad(v_q, ((0, 0), (0, pt), (0, 0), (0, 0)))
    Tp = k_q.shape[1]
    nt = Tp // bt
    qg = q.reshape(B, KV, G, hd)
    kv_scale = float(2.0 ** -frac_bits)
    sm_scale = float(1.0 / np.sqrt(hd))
    len_arr = jnp.asarray(kv_len, jnp.int32).reshape(1)

    out = pl.pallas_call(
        functools.partial(_kv_attn_kernel, nt=nt, bt=bt, kv_scale=kv_scale,
                          sm_scale=sm_scale),
        grid=(B, KV, nt),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # kv_len scalar
            pl.BlockSpec((1, 1, G, hd), lambda b, k, t: (b, k, 0, 0)),
            pl.BlockSpec((1, bt, 1, hd), lambda b, k, t: (b, t, k, 0)),
            pl.BlockSpec((1, bt, 1, hd), lambda b, k, t: (b, t, k, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, k, t: (b, k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),    # m
            pltpu.VMEM((G, 1), jnp.float32),    # l
            pltpu.VMEM((G, hd), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(len_arr, qg, k_q, v_q)
    return out.reshape(B, H, hd)
