"""Dense int8 KV-cache decode attention — thin wrapper over the paged kernel.

The original standalone Pallas kernel was absorbed into
``paged_kv_attention.py``: a contiguous (B, T, KV, hd) cache is just the
special case of a paged pool whose page table is the identity mapping
(sequence b's page p is pool page b * NP + p) and whose per-page scales are
all the layer's Q(I,F) scale 2^-F. Tile size ``block_t`` becomes the page
size, so the VMEM working set and the online-softmax loop structure are
unchanged from the old kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .paged_kv_attention import paged_kv_attention_decode


@functools.partial(jax.jit, static_argnames=("int_bits", "frac_bits",
                                             "block_t", "interpret"))
def kv_attention_decode(q, k_q, v_q, kv_len, *, int_bits: int,
                        frac_bits: int, block_t: int = 512,
                        interpret: bool = False):
    """q: (B, H, hd) float; k_q/v_q: (B, T, KV, hd) int8 Q(I,F) grid;
    kv_len: scalar int32. Returns (B, H, hd) float32."""
    del int_bits  # range already encoded in the stored grid
    B, H, hd = q.shape
    T, KV = k_q.shape[1], k_q.shape[2]
    ps = min(block_t, T)
    pt = (-T) % ps
    if pt:
        k_q = jnp.pad(k_q, ((0, 0), (0, pt), (0, 0), (0, 0)))
        v_q = jnp.pad(v_q, ((0, 0), (0, pt), (0, 0), (0, 0)))
    NP = k_q.shape[1] // ps
    k_pages = k_q.reshape(B * NP, ps, KV, hd)
    v_pages = v_q.reshape(B * NP, ps, KV, hd)
    page_table = jnp.arange(B * NP, dtype=jnp.int32).reshape(B, NP)
    scale = jnp.full((B * NP,), 2.0 ** -frac_bits, jnp.float32)
    lens = jnp.full((B,), jnp.asarray(kv_len, jnp.int32))
    return paged_kv_attention_decode(
        q, k_pages, v_pages, scale, scale, page_table, lens, bits=8,
        interpret=interpret)
