"""Pallas kernel: GQA decode attention over a paged, quantized KV cache.

Generalizes ``kv_attention.py``'s dense int8 kernel to the paged pool of
``repro.core.paged_kv``: KV history lives in fixed-size pages scattered
through a shared pool, each page stored in its quantized container (int8
grid, or a 4-bit grid lane-packed into int32 words along the head dim) with a
per-page dequant scale. The dense kernel is now a thin wrapper that builds an
identity page table (see ``kv_attention.py``).

Reachable via ``ops.paged_kv_attention`` (oracle-verified in
tests/test_kernels.py); the serving forward currently uses the equivalent
jnp gather path in ``core.paged_kv`` to stay bitwise-identical to the dense
cache — see the ROADMAP item on routing TPU decode through this kernel.

The page table and per-sequence lengths ride as **scalar-prefetch** operands
(`pltpu.PrefetchScalarGridSpec`): the BlockSpec index maps read
``page_table[b, p]`` to pick which pool page the next DMA fetches, so the
gather happens in the pipeline, not the kernel body — the standard TPU paged
attention pattern. In VMEM each page is unpacked (for sub-byte containers),
dequantized by its page scale, and folded into the online-softmax state.

Grid (B, KV, NP), NP innermost sequential; (m, l, acc) scratch carries
across pages. Unused page-table entries must point at a valid pool page
(page 0 / scratch) — their positions are masked by ``kv_len``. ``kv_len``
must be >= 1 per row, else the masked softmax degenerates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.qtensor import unpack_bits

NEG_INF = -1e30


def _dequant(x, scale, *, bits, head_dim):
    """(ps, hdw) stored page -> (ps, head_dim) f32 values.

    Shares the lane-unpack convention with core.qtensor (pure jnp right
    shifts, safe on TPU) so kernel == container == oracle. The per-page
    scale applies to every container, float pages included (writers keep
    their scales at 1.0)."""
    if 0 < bits < 8:
        x = unpack_bits(x, bits, head_dim)
    return x.astype(jnp.float32) * scale


def _paged_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                  o_ref, m_ref, l_ref, acc_ref, *, np_, ps, bits, head_dim,
                  sm_scale):
    b, p = pl.program_id(0), pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * sm_scale               # (G, hd)
    k = _dequant(k_ref[0, :, 0], ks_ref[0, 0], bits=bits,
                 head_dim=head_dim)                              # (ps, hd)
    v = _dequant(v_ref[0, :, 0], vs_ref[0, 0], bits=bits,
                 head_dim=head_dim)                              # (ps, hd)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)      # (G, ps)
    pos = p * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
    s = jnp.where(pos < len_ref[b], s, NEG_INF)

    m_prev = m_ref[...]                                          # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    pexp = jnp.exp(s - m_new)                                    # (G, ps)
    corr = jnp.exp(m_prev - m_new)                               # (G, 1)
    l_ref[...] = l_ref[...] * corr + pexp.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + \
        jnp.dot(pexp, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(p == np_ - 1)
    def _fin():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def paged_kv_attention_decode(q, k_pages, v_pages, k_scale, v_scale,
                              page_table, kv_len, *, bits: int = 8,
                              interpret: bool = False):
    """Decode attention over a paged quantized KV pool.

    q: (B, H, hd) float — one new token per sequence.
    k_pages/v_pages: (P, ps, KV, hdw) — int8 grid (bits=8), int32 lane-packed
        words with hdw = hd * bits / 32 (bits < 8), or float (bits=0).
    k_scale/v_scale: (P,) f32 per-page dequant scales (value = grid * scale).
    page_table: (B, NP) int32 pool-page ids; unused entries must reference a
        valid page (use the scratch page 0).
    kv_len: (B,) int32 valid history length per sequence (>= 1).
    Returns (B, H, hd) float32.
    """
    B, H, hd = q.shape
    P, ps, KV, hdw = k_pages.shape
    NP = page_table.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    sm_scale = float(1.0 / np.sqrt(hd))
    pt = jnp.asarray(page_table, jnp.int32)
    lens = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (B,))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,            # page_table, kv_len
        grid=(B, KV, NP),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, k, p, pt, ln: (b, k, 0, 0)),
            pl.BlockSpec((1, ps, 1, hdw),
                         lambda b, k, p, pt, ln: (pt[b, p], 0, k, 0)),
            pl.BlockSpec((1, ps, 1, hdw),
                         lambda b, k, p, pt, ln: (pt[b, p], 0, k, 0)),
            pl.BlockSpec((1, 1), lambda b, k, p, pt, ln: (pt[b, p], 0)),
            pl.BlockSpec((1, 1), lambda b, k, p, pt, ln: (pt[b, p], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, k, p, pt, ln: (b, k, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),    # m
            pltpu.VMEM((G, 1), jnp.float32),    # l
            pltpu.VMEM((G, hd), jnp.float32),   # acc
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, np_=NP, ps=ps, bits=bits,
                          head_dim=hd, sm_scale=sm_scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32),
        interpret=interpret,
    )(pt, lens, qg, k_pages, v_pages,
      k_scale.reshape(P, 1), v_scale.reshape(P, 1))
    return out.reshape(B, H, hd)
