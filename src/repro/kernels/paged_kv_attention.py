"""Pallas kernel: GQA variable-length attention over a paged, quantized KV
cache — ONE kernel for chunked prefill (S >= 1) and decode (S == 1).

Generalizes ``kv_attention.py``'s dense int8 kernel to the paged pool of
``repro.core.paged_kv``: KV history lives in fixed-size pages scattered
through a shared pool, each page stored in its quantized container (int8
grid, or a 4-bit grid lane-packed into int32 words along the head dim) with a
per-page dequant scale. The dense kernel is a thin wrapper that builds an
identity page table (see ``kv_attention.py``), and the historical decode
entry point (:func:`paged_kv_attention_decode`) is now the single-query-row
special case of the chunk kernel below.

Reachable via ``ops.paged_kv_attention`` / ``ops.paged_kv_attention_chunk``
(oracle-verified in tests/test_kernels.py); the serving forward routes BOTH
bucketed chunk prefill and decode through here under ``--attn-impl pallas``
(``models.attention.route_paged_attention``), with the jnp gather path kept
as the bitwise-reference mode.

The page table, per-row chunk start positions, and per-row valid lengths
ride as **scalar-prefetch** operands (`pltpu.PrefetchScalarGridSpec`): the
BlockSpec index maps read ``page_table[b, p]`` to pick which pool page the
next DMA fetches, so the gather happens in the pipeline, not the kernel body
— the standard TPU paged attention pattern. In VMEM each page is unpacked
(for sub-byte containers), dequantized by its page scale, and folded into
the online-softmax state.

Grid (B, KV, NQ, NP): NQ blocks of ``block_q`` chunk queries, NP pool pages
innermost sequential; (m, l, acc) scratch carries across pages per query
block. Each key position is masked **causally against its per-row absolute
query positions** (``q_start[b] + query index``) and against the row's
``kv_len`` — partial last pages fall out of the length mask, padded chunk
tails (positions past the row's real tokens) produce garbage rows that no
caller reads (their pool writes were already scratch-redirected by
``paged_update``). Unused page-table entries must point at a valid pool
page (page 0 / scratch) — their positions are masked the same way.
``kv_len`` must be >= 1 per row with at least one real query, else the
masked softmax degenerates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.qtensor import unpack_bits

NEG_INF = -1e30


def _dequant(x, scale, *, bits, head_dim):
    """(ps, hdw) stored page -> (ps, head_dim) f32 values.

    Shares the lane-unpack convention with core.qtensor (pure jnp right
    shifts, safe on TPU) so kernel == container == oracle. The per-page
    scale applies to every container, float pages included (writers keep
    their scales at 1.0)."""
    if 0 < bits < 8:
        x = unpack_bits(x, bits, head_dim)
    return x.astype(jnp.float32) * scale


def _chunk_kernel(pt_ref, qs_ref, len_ref, q_ref, k_ref, v_ref, ks_ref,
                  vs_ref, o_ref, m_ref, l_ref, acc_ref, *, np_, ps, bq, g,
                  bits, head_dim, sm_scale):
    b, qb, p = pl.program_id(0), pl.program_id(2), pl.program_id(3)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (bq, G, hd)
    q = q.reshape(bq * g, head_dim) * sm_scale
    k = _dequant(k_ref[0, :, 0], ks_ref[0, 0], bits=bits,
                 head_dim=head_dim)                      # (ps, hd)
    v = _dequant(v_ref[0, :, 0], vs_ref[0, 0], bits=bits,
                 head_dim=head_dim)                      # (ps, hd)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq*G, ps)
    pos = p * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
    # causal mask against the ABSOLUTE position of each query row: flattened
    # row r is chunk query r // G, at position q_start[b] + qb*bq + r // G
    qrow = jax.lax.broadcasted_iota(jnp.int32, (bq * g, 1), 0) // g
    q_pos = qs_ref[b] + qb * bq + qrow                   # (bq*G, 1)
    s = jnp.where((pos <= q_pos) & (pos < len_ref[b]), s, NEG_INF)

    m_prev = m_ref[...]                                  # (bq*G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    pexp = jnp.exp(s - m_new)                            # (bq*G, ps)
    corr = jnp.exp(m_prev - m_new)                       # (bq*G, 1)
    l_ref[...] = l_ref[...] * corr + pexp.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + \
        jnp.dot(pexp, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(p == np_ - 1)
    def _fin():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)
                       ).reshape(bq, g, head_dim).astype(o_ref.dtype)


def _chunk_kernel_kvblock(pt_ref, qs_ref, len_ref, q_ref, k_ref, v_ref,
                          ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                          np_, ps, bq, kv, g, bits, head_dim, sm_scale):
    """KV-head-blocked variant of :func:`_chunk_kernel`: one grid step
    fetches the WHOLE pool page — all KV heads, (1, ps, KV, hdw)
    contiguous in the pool layout — instead of one head's (1, ps, 1, hdw)
    slice, collapsing the grid from (B, KV, NQ, NP) to (B, NQ, NP). KV x
    fewer pipeline steps and KV x fewer (KV x larger, fully contiguous)
    page DMAs per query block, paid for with KV x the VMEM scratch and
    per-step compute. The softmax state is carried for all heads at once
    (rows = KV * bq * G); the per-head dots are a static python loop
    (KV is small), so total MXU work is identical to the per-head grid.
    The math is the same sequence of ops per row, but dot operands are
    strided head-slices rather than contiguous blocks, so outputs agree
    with the per-head kernel only to float ULPs (exact for fp pages) —
    which is why ``block_kv`` defaults to off wherever bitwise serving
    identity is pinned."""
    b, qb, p = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                     # (bq, KV, G, hd)
    k = _dequant(k_ref[0], ks_ref[0, 0], bits=bits,
                 head_dim=head_dim)                      # (ps, KV, hd)
    v = _dequant(v_ref[0], vs_ref[0, 0], bits=bits,
                 head_dim=head_dim)                      # (ps, KV, hd)

    # one causal/length mask, shared by every kv head
    pos = p * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
    qrow = jax.lax.broadcasted_iota(jnp.int32, (bq * g, 1), 0) // g
    q_pos = qs_ref[b] + qb * bq + qrow                   # (bq*G, 1)
    mask = (pos <= q_pos) & (pos < len_ref[b])           # (bq*G, ps)

    scores = []
    for h in range(kv):                                  # static unroll
        qh = (q[:, h].reshape(bq * g, head_dim) * sm_scale)
        s = jnp.dot(qh, k[:, h].T,
                    preferred_element_type=jnp.float32)  # (bq*G, ps)
        scores.append(jnp.where(mask, s, NEG_INF))
    s = jnp.concatenate(scores, axis=0)                  # (KV*bq*G, ps)

    m_prev = m_ref[...]                                  # (KV*bq*G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    pexp = jnp.exp(s - m_new)                            # (KV*bq*G, ps)
    corr = jnp.exp(m_prev - m_new)                       # (KV*bq*G, 1)
    l_ref[...] = l_ref[...] * corr + pexp.sum(axis=1, keepdims=True)
    upd = jnp.concatenate(
        [jnp.dot(pexp[h * bq * g:(h + 1) * bq * g], v[:, h],
                 preferred_element_type=jnp.float32) for h in range(kv)],
        axis=0)                                          # (KV*bq*G, hd)
    acc_ref[...] = acc_ref[...] * corr + upd
    m_ref[...] = m_new

    @pl.when(p == np_ - 1)
    def _fin():
        o = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
             ).reshape(kv, bq, g, head_dim)
        o_ref[0] = jnp.moveaxis(o, 0, 1).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bits", "block_q", "block_kv",
                                    "interpret"))
def paged_kv_attention_chunk(q, k_pages, v_pages, k_scale, v_scale,
                             page_table, q_start, kv_len, *, bits: int = 8,
                             block_q: int = 8, block_kv: bool = False,
                             interpret: bool = False):
    """Variable-length chunk attention over a paged quantized KV pool.

    q: (B, S, H, hd) float — S chunk queries per sequence (S == 1: decode).
    k_pages/v_pages: (P, ps, KV, hdw) — int8 grid (bits=8), int32 lane-packed
        words with hdw = hd * bits / 32 (bits < 8), or float (bits=0).
    k_scale/v_scale: (P,) f32 per-page dequant scales (value = grid * scale).
    page_table: (B, NP) int32 pool-page ids; unused entries must reference a
        valid page (use the scratch page 0).
    q_start: (B,) int32 absolute position of chunk token 0 per row (== the
        row's cache write offset); query i sits at ``q_start + i`` and
        attends keys causally up to that position.
    kv_len: (B,) int32 valid history length per row INCLUDING the chunk's
        real tokens (>= 1). For padded chunks, query rows past the valid
        tail produce garbage outputs that no caller reads.
    bits must match the page container. Returns (B, S, H, hd) float32.

    ``block_kv=True`` selects the KV-head-blocked pipeline (grid
    (B, NQ, NP), whole pages per DMA — see :func:`_chunk_kernel_kvblock`):
    same math, fewer/larger page fetches. Default off — the per-head grid
    is the shipped reference whose outputs the serving identity tests pin.
    """
    B, S, H, hd = q.shape
    P, ps, KV, hdw = k_pages.shape
    NP = page_table.shape[1]
    G = H // KV
    bq = max(1, min(block_q, S))
    nq = -(-S // bq)
    sp = nq * bq
    qg = jnp.moveaxis(q.reshape(B, S, KV, G, hd), 1, 2)  # (B, KV, S, G, hd)
    if sp != S:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, sp - S), (0, 0), (0, 0)))
    sm_scale = float(1.0 / np.sqrt(hd))
    pt = jnp.asarray(page_table, jnp.int32)
    qs = jnp.broadcast_to(jnp.asarray(q_start, jnp.int32).reshape(-1), (B,))
    lens = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (B,))

    if block_kv:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,        # page_table, q_start, kv_len
            grid=(B, nq, NP),
            in_specs=[
                pl.BlockSpec((1, bq, KV, G, hd),
                             lambda b, qb, p, pt, qs, ln: (b, qb, 0, 0, 0)),
                pl.BlockSpec((1, ps, KV, hdw),
                             lambda b, qb, p, pt, qs, ln:
                             (pt[b, p], 0, 0, 0)),
                pl.BlockSpec((1, ps, KV, hdw),
                             lambda b, qb, p, pt, qs, ln:
                             (pt[b, p], 0, 0, 0)),
                pl.BlockSpec((1, 1), lambda b, qb, p, pt, qs, ln:
                             (pt[b, p], 0)),
                pl.BlockSpec((1, 1), lambda b, qb, p, pt, qs, ln:
                             (pt[b, p], 0)),
            ],
            out_specs=pl.BlockSpec((1, bq, KV, G, hd),
                                   lambda b, qb, p, pt, qs, ln:
                                   (b, qb, 0, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((KV * bq * G, 1), jnp.float32),    # m
                pltpu.VMEM((KV * bq * G, 1), jnp.float32),    # l
                pltpu.VMEM((KV * bq * G, hd), jnp.float32),   # acc
            ],
        )
        # the blocked kernel wants the (B, S, KV, G, hd) layout (whole
        # token rows, all heads adjacent), not the per-head (B, KV, S, ...)
        qb_in = q.reshape(B, S, KV, G, hd)
        if sp != S:
            qb_in = jnp.pad(qb_in, ((0, 0), (0, sp - S), (0, 0), (0, 0),
                                    (0, 0)))
        out = pl.pallas_call(
            functools.partial(_chunk_kernel_kvblock, np_=NP, ps=ps, bq=bq,
                              kv=KV, g=G, bits=bits, head_dim=hd,
                              sm_scale=sm_scale),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((B, sp, KV, G, hd), jnp.float32),
            interpret=interpret,
        )(pt, qs, lens, qb_in, k_pages, v_pages,
          k_scale.reshape(P, 1), v_scale.reshape(P, 1))
        return out[:, :S].reshape(B, S, H, hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,            # page_table, q_start, kv_len
        grid=(B, KV, nq, NP),
        in_specs=[
            pl.BlockSpec((1, 1, bq, G, hd),
                         lambda b, k, qb, p, pt, qs, ln: (b, k, qb, 0, 0)),
            pl.BlockSpec((1, ps, 1, hdw),
                         lambda b, k, qb, p, pt, qs, ln: (pt[b, p], 0, k, 0)),
            pl.BlockSpec((1, ps, 1, hdw),
                         lambda b, k, qb, p, pt, qs, ln: (pt[b, p], 0, k, 0)),
            pl.BlockSpec((1, 1), lambda b, k, qb, p, pt, qs, ln:
                         (pt[b, p], 0)),
            pl.BlockSpec((1, 1), lambda b, k, qb, p, pt, qs, ln:
                         (pt[b, p], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, G, hd),
                               lambda b, k, qb, p, pt, qs, ln:
                               (b, k, qb, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq * G, 1), jnp.float32),    # m
            pltpu.VMEM((bq * G, 1), jnp.float32),    # l
            pltpu.VMEM((bq * G, hd), jnp.float32),   # acc
        ],
    )
    out = pl.pallas_call(
        functools.partial(_chunk_kernel, np_=NP, ps=ps, bq=bq, g=G,
                          bits=bits, head_dim=hd, sm_scale=sm_scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, sp, G, hd), jnp.float32),
        interpret=interpret,
    )(pt, qs, lens, qg, k_pages, v_pages,
      k_scale.reshape(P, 1), v_scale.reshape(P, 1))
    return jnp.moveaxis(out[:, :, :S], 1, 2).reshape(B, S, H, hd)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def paged_kv_attention_decode(q, k_pages, v_pages, k_scale, v_scale,
                              page_table, kv_len, *, bits: int = 8,
                              interpret: bool = False):
    """Decode attention over a paged quantized KV pool — the S == 1 special
    case of :func:`paged_kv_attention_chunk` (the sole query row sits at
    ``kv_len - 1``, so the causal bound collapses into the length mask).

    q: (B, H, hd) float — one new token per sequence; other shapes as in
    the chunk kernel. kv_len: (B,) int32 valid history length (>= 1).
    Returns (B, H, hd) float32.
    """
    B = q.shape[0]
    lens = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (B,))
    out = paged_kv_attention_chunk(
        q[:, None], k_pages, v_pages, k_scale, v_scale, page_table,
        lens - 1, lens, bits=bits, block_q=1, interpret=interpret)
    return out[:, 0]
