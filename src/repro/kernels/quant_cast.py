"""Pallas kernel: tiled fake-quant Q(I,F) — the paper's memory-boundary op.

HBM -> VMEM tile -> (scale, round-half-away, clip, rescale) on the VPU ->
VMEM -> HBM. Tile (256, 512) fp32 = 512 KB in / 512 KB out, comfortably
inside v5e's ~16 MB VMEM with double buffering; last dim 512 = 4 lanes of
128. Format parameters are compile-time constants (per-layer formats are a
handful of variants, each a tiny kernel specialization).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK = (256, 512)


def _quant_cast_kernel(x_ref, o_ref, *, scale, qmin, qmax):
    x = x_ref[...].astype(jnp.float32)
    s = x * scale
    q = jnp.trunc(s + jnp.copysign(0.5, s))       # round half away from zero
    q = jnp.clip(q, qmin, qmax)
    o_ref[...] = (q * (1.0 / scale)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("int_bits", "frac_bits", "block",
                                    "interpret"))
def quant_cast_2d(x, *, int_bits: int, frac_bits: int,
                  block=DEFAULT_BLOCK, interpret: bool = False):
    """x: (M, N). Returns fake-quantized array, same shape/dtype."""
    M, N = x.shape
    bm, bn = min(block[0], M), min(block[1], N)
    pm, pn = (-M) % bm, (-N) % bn
    xp = jnp.pad(x, ((0, pm), (0, pn))) if (pm or pn) else x
    Mp, Np = xp.shape
    scale = float(2 ** frac_bits)
    qmax = float(2 ** (int_bits + frac_bits - 1) - 1)
    qmin = -float(2 ** (int_bits + frac_bits - 1))
    out = pl.pallas_call(
        functools.partial(_quant_cast_kernel, scale=scale, qmin=qmin,
                          qmax=qmax),
        grid=(Mp // bm, Np // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=interpret,
    )(xp)
    return out[:M, :N] if (pm or pn) else out
