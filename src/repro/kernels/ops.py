"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on TPU —
the same call sites serve tests and production. Each op has a ``*_ref``
oracle in kernels.ref; tests/test_kernels.py sweeps shapes/dtypes
(hypothesis) asserting allclose.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .kv_attention import kv_attention_decode
from .paged_kv_attention import \
    paged_kv_attention_chunk as _paged_kv_attention_chunk
from .paged_kv_attention import paged_kv_attention_decode
from .pack import pack_2d, unpack_2d, values_per_word
from .quant_cast import quant_cast_2d
from .quant_matmul import quant_matmul


def _default_interpret() -> bool:
    return jax.default_backend() == "cpu"


def quant_cast(x, int_bits: int, frac_bits: int, *, interpret=None):
    """Fake-quant Q(I,F) on arbitrary-rank input (kernel works on 2-D)."""
    interpret = _default_interpret() if interpret is None else interpret
    shape = x.shape
    x2 = x.reshape(-1, shape[-1]) if x.ndim != 2 else x
    if x2.ndim == 1:
        x2 = x2[None, :]
    y = quant_cast_2d(x2, int_bits=int_bits, frac_bits=frac_bits,
                      interpret=interpret)
    return y.reshape(shape)


def pack(q, bits: int, *, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    shape = q.shape
    q2 = q.reshape(-1, shape[-1])
    w = pack_2d(q2, bits=bits, interpret=interpret)
    return w.reshape(*shape[:-1], shape[-1] // values_per_word(bits))


def unpack(w, bits: int, *, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    shape = w.shape
    w2 = w.reshape(-1, shape[-1])
    q = unpack_2d(w2, bits=bits, interpret=interpret)
    return q.reshape(*shape[:-1], shape[-1] * values_per_word(bits))


def qmatmul(a, wq, scales, *, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return quant_matmul(a, wq, scales, interpret=interpret)


def kv_attention(q, k_q, v_q, kv_len, *, int_bits: int, frac_bits: int,
                 interpret=None, block_t: int = 512):
    interpret = _default_interpret() if interpret is None else interpret
    return kv_attention_decode(q, k_q, v_q, kv_len, int_bits=int_bits,
                               frac_bits=frac_bits, block_t=block_t,
                               interpret=interpret)


def paged_kv_attention(q, k_pages, v_pages, k_scale, v_scale, page_table,
                       kv_len, *, bits: int = 8, interpret=None):
    """Decode attention over a paged quantized KV pool (see
    kernels.paged_kv_attention for shapes). bits: 8 (int8 pages), 4
    (int32 lane-packed pages) or 0 (float pages)."""
    interpret = _default_interpret() if interpret is None else interpret
    return paged_kv_attention_decode(q, k_pages, v_pages, k_scale, v_scale,
                                     page_table, kv_len, bits=bits,
                                     interpret=interpret)


def paged_kv_attention_chunk(q, k_pages, v_pages, k_scale, v_scale,
                             page_table, q_start, kv_len, *, bits: int = 8,
                             block_q: int = 8, block_kv: bool = False,
                             interpret=None):
    """Variable-length (S >= 1) chunk attention over a paged quantized KV
    pool — the prefill-chunk generalization of ``paged_kv_attention`` (see
    kernels.paged_kv_attention for shapes). q: (B, S, H, hd); ``q_start``
    (B,) is the absolute position of each row's first chunk query.
    ``block_kv=True`` selects the KV-head-blocked pipeline (whole pages
    per DMA; same math, fewer grid steps — see the kernel docstring)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _paged_kv_attention_chunk(q, k_pages, v_pages, k_scale, v_scale,
                                     page_table, q_start, kv_len, bits=bits,
                                     block_q=block_q, block_kv=block_kv,
                                     interpret=interpret)


__all__ = ["quant_cast", "pack", "unpack", "qmatmul", "kv_attention",
           "paged_kv_attention", "paged_kv_attention_chunk", "ref"]
