"""Pallas kernels: N-bit <-> int32 lane packing.

TPU HBM is byte-addressed with 32-bit-friendly layouts; "N-bit memory" from
the paper becomes k = 32/N grid values packed into one int32 lane
(DESIGN.md §3 hardware adaptation). The packed tensor's footprint is truly
N/32 of an int32 tensor — this is what the traffic/footprint numbers in
EXPERIMENTS.md are backed by at runtime.

pack : (M, N)  int32 grid vals -> (M, N/vpw) int32 words
unpack: (M, N/vpw) int32 words -> (M, N)    int32 grid vals (sign-extended)

Tiles keep the UNPACKED side at (256, 512) int32 (512 KB) and the packed
side at (256, 512/vpw); both fit VMEM with double buffering. Bit ops run on
the VPU; uint32 shifts avoid signed-overflow traps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def values_per_word(bits: int) -> int:
    assert bits in (2, 4, 8, 16), bits
    return 32 // bits


def _pack_kernel(x_ref, o_ref, *, bits):
    vpw = 32 // bits
    x = x_ref[...]
    mask = jnp.uint32((1 << bits) - 1)
    qu = x.astype(jnp.uint32) & mask
    grp = qu.reshape(x.shape[0], x.shape[1] // vpw, vpw)
    word = jnp.zeros(grp.shape[:-1], jnp.uint32)
    for i in range(vpw):  # static unroll: vpw in {2,4,8,16}
        word = word | (grp[..., i] << jnp.uint32(i * bits))
    o_ref[...] = jax.lax.bitcast_convert_type(word, jnp.int32)


def _unpack_kernel(w_ref, o_ref, *, bits):
    vpw = 32 // bits
    wu = jax.lax.bitcast_convert_type(w_ref[...], jnp.uint32)
    mask = jnp.uint32((1 << bits) - 1)
    sign = jnp.uint32(1 << (bits - 1))
    fields = (wu[..., None] >> (jnp.arange(vpw, dtype=jnp.uint32) * bits)) \
        & mask
    vals = (fields ^ sign).astype(jnp.int32) - jnp.int32(sign)
    o_ref[...] = vals.reshape(wu.shape[0], wu.shape[1] * vpw)


@functools.partial(jax.jit, static_argnames=("bits", "block_rows",
                                             "interpret"))
def pack_2d(q, *, bits: int, block_rows: int = 256,
            interpret: bool = False):
    """q: (M, N) int32 grid values, N % (32/bits) == 0."""
    vpw = values_per_word(bits)
    M, N = q.shape
    assert N % vpw == 0, (N, vpw)
    bm = min(block_rows, M)
    pm = (-M) % bm
    qp = jnp.pad(q, ((0, pm), (0, 0))) if pm else q
    out = pl.pallas_call(
        functools.partial(_pack_kernel, bits=bits),
        grid=(qp.shape[0] // bm,),
        in_specs=[pl.BlockSpec((bm, N), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, N // vpw), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], N // vpw), jnp.int32),
        interpret=interpret,
    )(qp)
    return out[:M] if pm else out


@functools.partial(jax.jit, static_argnames=("bits", "block_rows",
                                             "interpret"))
def unpack_2d(w, *, bits: int, block_rows: int = 256,
              interpret: bool = False):
    """w: (M, W) int32 packed words -> (M, W * 32/bits) int32 values."""
    vpw = values_per_word(bits)
    M, W = w.shape
    bm = min(block_rows, M)
    pm = (-M) % bm
    wp = jnp.pad(w, ((0, pm), (0, 0))) if pm else w
    out = pl.pallas_call(
        functools.partial(_unpack_kernel, bits=bits),
        grid=(wp.shape[0] // bm,),
        in_specs=[pl.BlockSpec((bm, W), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, W * vpw), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((wp.shape[0], W * vpw), jnp.int32),
        interpret=interpret,
    )(wp)
    return out[:M] if pm else out
