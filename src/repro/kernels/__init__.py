"""Pallas TPU kernels for the paper's perf-critical memory-boundary ops.

quant_cast    — tiled fake-quant Q(I,F) (paper §2.1 conversion)
pack/unpack   — k N-bit values <-> int32 lanes ("N-bit memory" on TPU HBM)
quant_matmul  — int8-weight matmul, dequant-in-VMEM, per-channel scales
kv_attention  — decode attention over a dense int8-quantized KV cache
paged_kv_attention — decode attention over a paged int8/int4 KV pool
                     (page-table gather via scalar prefetch; kv_attention
                     is its identity-page-table special case)

Use via ``repro.kernels.ops`` (jit'd, interpret-mode auto on CPU); oracles in
``repro.kernels.ref``.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
