"""Pallas TPU kernels for the paper's perf-critical memory-boundary ops.

quant_cast    — tiled fake-quant Q(I,F) (paper §2.1 conversion)
pack/unpack   — k N-bit values <-> int32 lanes ("N-bit memory" on TPU HBM)
quant_matmul  — int8-weight matmul, dequant-in-VMEM, per-channel scales
kv_attention  — decode attention over an int8-quantized KV cache

Use via ``repro.kernels.ops`` (jit'd, interpret-mode auto on CPU); oracles in
``repro.kernels.ref``.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
