"""Pallas kernel: A(bf16/f32) x W(int8 grid, per-channel scale) -> f32.

The paper's per-layer weight bits, made computable without a dequantized
weight copy in HBM: W ships int8 (optionally int4-packed via kernels.pack,
unpacked on the fly by the int4 variant), is dequantized TILE-BY-TILE in
VMEM, and feeds the MXU as fp32.

Blocking: (bm, bk) x (bk, bn) -> (bm, bn) with grid (M/bm, N/bn, K/bk);
K innermost (sequential) so the output block accumulates in place across K
steps. Defaults bm=bn=256, bk=512: VMEM footprint
  A 256x512 f32 = 512 KB, W 512x256 int8 = 128 KB, O 256x256 f32 = 256 KB
and all matmul dims are multiples of 128 (MXU-aligned). Per-channel scales
apply once, on the LAST K step (one multiply per output element total).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qmm_kernel(a_ref, w_ref, s_ref, o_ref, *, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)          # int8 -> f32 dequant in VMEM
    o_ref[...] += jnp.dot(a, w, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _scale():
        o_ref[...] = o_ref[...] * s_ref[...].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def quant_matmul(a, wq, scales, *, block=(256, 256, 512),
                 interpret: bool = False):
    """a: (M, K) float; wq: (K, N) int8/int16; scales: (N,) f32.
    Returns (M, N) f32 = a @ (wq * scales)."""
    M, K = a.shape
    K2, N = wq.shape
    assert K == K2 and scales.shape == (N,)
    bm, bn, bk = (min(block[0], M), min(block[1], N), min(block[2], K))
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        wq = jnp.pad(wq, ((0, pk), (0, pn)))
    if pn:
        scales = jnp.pad(scales, (0, pn))
    Mp, Kp = a.shape
    Np = wq.shape[1]
    nk = Kp // bk
    out = pl.pallas_call(
        functools.partial(_qmm_kernel, nk=nk),
        grid=(Mp // bm, Np // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        interpret=interpret,
    )(a, wq, scales[None, :])
    return out[:M, :N] if (pm or pn) else out
