"""Serving telemetry substrate: a unified metrics registry + a span tracer.

The serving stack (paged pool, prefix cache, quant tier, host tier, SLO
scheduler, fused step) grew one ad-hoc instance counter per feature and a
``verbose=True`` print block. This module replaces that with two small,
dependency-free primitives every layer shares:

* :class:`MetricsRegistry` — named **counters** (monotonic, resettable
  floats/ints), **gauges** (current-state values, either set directly or
  registered as zero-arg callbacks evaluated at read time), and
  **histograms** that keep every observation so p50/p99 extraction is
  EXACT (nearest-rank over the raw samples, no bucket interpolation).
  ``reset()``/``checkpoint()``/``since()`` give benchmarks one sanctioned
  way to split warmup from measurement instead of hand-zeroing attributes.
  The registry is process-wide *by convention* but injectable by
  construction: every component takes ``metrics=`` and defaults to its own
  private registry, and :class:`repro.launch.serve.BatchedServer` threads
  ONE registry through allocator, prefix cache, tiered pager and host/quant
  stores — so serve, tests and benches read a single source of truth.

* :class:`Tracer` — span/instant events on a monotonic clock
  (``time.perf_counter``), with per-request lifecycle bookkeeping:
  arrival -> admit/defer/reject -> prefill chunks -> decode spans ->
  preempt/offload/resume -> requant/demote/promote -> finish. Events
  export as **Chrome trace-event JSON** (``chrome://tracing`` / Perfetto:
  ``X`` complete spans, ``i`` instants, ``M`` track names; pid 0 is the
  server, tid 0 the engine, tid 1+rid one track per request) and the
  request records reduce to SLO metrics: per-request **TTFT** (arrival to
  first emitted token, wall), **TPOT** (decode seconds per generated token
  after the first), and **goodput** — the fraction of offered requests
  that finished by their ``deadline_step`` on the decode-step clock
  (no-deadline requests count as met iff they completed unrejected).

* :class:`NullTracer` — the disabled path: identical surface, every method
  a no-op. Telemetry lives entirely OUTSIDE jitted code, so
  ``--metrics off`` is bitwise-identical to the pre-telemetry server by
  construction (the same contract ``--kv-adapt off`` keeps).

* :class:`MetricsSnapshotter` — a periodic JSONL metrics stream: one
  ``registry.snapshot()`` line every N scheduler cycles.

Counter *migration* from legacy instance attributes is done with
:class:`metric_attr`: a data descriptor that maps ``srv.prefill_forwards``
reads/writes onto ``srv.metrics.counter("serve.prefill_forwards")`` — every
existing call site (``+= 1``, hand-zeroing, bench reads) keeps working
while the registry becomes the storage.
"""
from __future__ import annotations

import collections
import contextlib
import json
import logging
import math
import time
from typing import Callable, Dict, List, Optional

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "MetricsSnapshotter", "Tracer", "NullTracer", "make_tracer",
           "metric_attr", "default_registry", "percentile", "Ewma",
           "SLOMonitor", "PAGER_TID"]

logger = logging.getLogger(__name__)

# dedicated Chrome-trace track for async pager transfers: their spans
# OVERLAP engine decode spans by design (that overlap is the feature being
# proven), and per-track span nesting is an invariant elsewhere — so they
# get their own tid, far above any 1+rid request track
PAGER_TID = 1_000_000


def percentile(values, p: float):
    """Exact nearest-rank percentile of ``values`` (no interpolation).

    ``p`` in [0, 100]. Returns None on an empty input — absence is a fact
    worth distinguishing from 0.0 in SLO summaries."""
    if not values:
        return None
    xs = sorted(values)
    k = max(1, math.ceil(p / 100.0 * len(xs)))
    return xs[min(k, len(xs)) - 1]


def _as_number(v: float):
    """Ints stay ints in snapshots/prints (counters are mostly counts)."""
    return int(v) if float(v).is_integer() else float(v)


class Counter:
    """A named, monotonically-incremented (but resettable) number.

    Float storage so wall-second accumulators (``prefill_s``) ride the same
    type; ``value`` reads back as int whenever integral."""

    __slots__ = ("name", "_v")

    def __init__(self, name: str):
        self.name = name
        self._v = 0.0

    def inc(self, n: float = 1) -> None:
        self._v += n

    @property
    def value(self):
        return _as_number(self._v)

    @value.setter
    def value(self, v: float) -> None:
        self._v = float(v)

    def reset(self) -> None:
        self._v = 0.0


class Gauge:
    """Current-state value: set directly (``set``) or backed by a zero-arg
    callback (``fn``) evaluated at read time — so pool occupancy / tier
    bytes are always live without any update discipline."""

    __slots__ = ("name", "_v", "fn")

    def __init__(self, name: str, fn: Optional[Callable[[], float]] = None):
        self.name = name
        self._v = 0.0
        self.fn = fn

    def set(self, v: float) -> None:
        self._v = float(v)

    @property
    def value(self):
        return _as_number(self.fn() if self.fn is not None else self._v)


class Histogram:
    """All-samples histogram: p50/p99 are exact nearest-rank extractions
    over the raw observations (the scales here — requests, cycles, pages —
    are far below where reservoir sketches would earn their error)."""

    __slots__ = ("name", "values")

    def __init__(self, name: str):
        self.name = name
        self.values: List[float] = []

    def observe(self, v: float) -> None:
        self.values.append(float(v))

    @property
    def count(self) -> int:
        return len(self.values)

    def percentile(self, p: float):
        return percentile(self.values, p)

    def summary(self) -> dict:
        n = len(self.values)
        if n == 0:
            return {"count": 0}
        return {"count": n,
                "mean": sum(self.values) / n,
                "min": min(self.values), "max": max(self.values),
                "p50": self.percentile(50), "p99": self.percentile(99)}

    def reset(self) -> None:
        self.values = []


class MetricsRegistry:
    """Injectable named-metric store: counters, gauges, histograms.

    Metric objects are created on first access (``counter(name)`` etc.) and
    stable thereafter, so hot paths can hold the object instead of paying
    the dict lookup per event.

    ``namespace`` prefixes every metric name in *exported* views
    (:meth:`snapshot` / :meth:`export_name`) — internal access stays
    unprefixed, so a component reading ``registry.gauge("slo.x")`` works
    identically whether its server is standalone or one replica of a
    multi-replica front (each replica gets
    ``MetricsRegistry(namespace="replica0")`` etc. and the merged JSONL
    stream keeps the streams apart)."""

    def __init__(self, namespace: str = ""):
        self.namespace = namespace
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def export_name(self, name: str) -> str:
        return f"{self.namespace}.{name}" if self.namespace else name

    # -- access -------------------------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def register_gauge(self, name: str,
                       fn: Callable[[], float]) -> Gauge:
        """(Re)bind gauge ``name`` to a live zero-arg callback."""
        g = self.gauge(name)
        g.fn = fn
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name)
        return h

    def value(self, name: str):
        """Read any metric by name (counter > gauge > histogram count)."""
        if name in self._counters:
            return self._counters[name].value
        if name in self._gauges:
            return self._gauges[name].value
        if name in self._histograms:
            return self._histograms[name].count
        raise KeyError(f"unknown metric {name!r}")

    # -- snapshot / reset ---------------------------------------------------
    def snapshot(self) -> dict:
        """One JSON-ready view of everything (gauge callbacks evaluated);
        keys carry the registry ``namespace`` prefix, if any."""
        ns = self.export_name
        return {
            "counters": {ns(n): c.value
                         for n, c in sorted(self._counters.items())},
            "gauges": {ns(n): g.value
                       for n, g in sorted(self._gauges.items())},
            "histograms": {ns(n): h.summary()
                           for n, h in sorted(self._histograms.items())},
        }

    def checkpoint(self) -> dict:
        """Mark the current counter values (warmup boundary). Pair with
        :meth:`since` to read measurement-window deltas without zeroing."""
        return {n: c.value for n, c in self._counters.items()}

    def since(self, checkpoint: dict) -> dict:
        """Counter deltas accumulated after ``checkpoint``."""
        return {n: _as_number(c.value - checkpoint.get(n, 0))
                for n, c in sorted(self._counters.items())}

    def reset(self) -> None:
        """Zero every counter and clear every histogram — the single
        sanctioned warmup/measurement boundary (benchmarks used to
        hand-zero individual server attributes; see ISSUE 8 satellite 1).
        Gauges are state, not accumulation, and are left alone."""
        for c in self._counters.values():
            c.reset()
        for h in self._histograms.values():
            h.reset()


_DEFAULT: Optional[MetricsRegistry] = None


def default_registry() -> MetricsRegistry:
    """The process-wide registry, for components not owned by a server.
    Everything in the serving path injects an explicit registry instead —
    two servers in one process (every A/B bench) must not share counters."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = MetricsRegistry()
    return _DEFAULT


class metric_attr:
    """Data descriptor mapping a legacy instance attribute onto a registry
    counter, so ``obj.prefill_forwards += 1`` and bench-side hand-zeroing
    keep working verbatim while ``obj.<registry_attr>`` holds the truth."""

    __slots__ = ("name", "registry_attr")

    def __init__(self, name: str, registry_attr: str = "metrics"):
        self.name = name
        self.registry_attr = registry_attr

    def __get__(self, obj, objtype=None):
        if obj is None:
            return self
        return getattr(obj, self.registry_attr).counter(self.name).value

    def __set__(self, obj, value) -> None:
        getattr(obj, self.registry_attr).counter(self.name).value = value


class Ewma:
    """Exponentially-weighted moving average; ``value`` is None until the
    first update (absence is distinguishable from 0.0)."""

    __slots__ = ("alpha", "value")

    def __init__(self, alpha: float = 0.2):
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        self.alpha = alpha
        self.value: Optional[float] = None

    def update(self, x: float) -> float:
        self.value = (float(x) if self.value is None
                      else self.alpha * float(x)
                      + (1.0 - self.alpha) * self.value)
        return self.value

    def get(self, default: float = 0.0) -> float:
        return default if self.value is None else self.value


class SLOMonitor:
    """Rolling-window SLO reductions, live DURING a run.

    ``Tracer.slo_summary()`` is exact but post-hoc; this keeps bounded
    deques of the last ``window`` finished requests and EWMAs of the
    queue/arrival/TPOT signals, and registers them as ``slo.*`` gauges so
    the JSONL snapshot stream (and the deadline-miss predictor) can read
    SLO health every cycle. Empty-window gauges read 0.0 —
    ``slo.window_requests`` disambiguates "no traffic yet" from "goodput
    actually 0". Host-side only: feeding it cannot change tokens.

    ``tpot_ref`` is a slow EWMA of the same TPOT stream — the run's own
    baseline decode speed — so ``tpot_ewma / tpot_ref`` gives the
    predictor a unitless slowdown signal without any hardware constant.
    """

    def __init__(self, registry: MetricsRegistry, window: int = 32,
                 alpha: float = 0.2):
        if window < 1:
            raise ValueError("window must be >= 1 request")
        self.registry = registry
        self.window = window
        self._ttft = collections.deque(maxlen=window)
        self._tpot = collections.deque(maxlen=window)
        self._met = collections.deque(maxlen=window)
        self._arrive_ts: Dict[int, float] = {}
        self._first_ts: Dict[int, float] = {}
        self.queue_depth = Ewma(alpha)
        self.arrival_rate = Ewma(alpha / 2)   # slower: spans burst gaps
        self.tpot = Ewma(alpha)
        self.tpot_ref = Ewma(alpha / 10)
        self._pending_arrivals = 0
        g = registry.register_gauge
        g("slo.window_requests", lambda: len(self._met))
        g("slo.window_goodput", lambda: self.window_goodput() or 0.0)
        g("slo.window_ttft_p50_s", lambda: self.window_ttft(50) or 0.0)
        g("slo.window_ttft_p99_s", lambda: self.window_ttft(99) or 0.0)
        g("slo.window_tpot_p50_s", lambda: self.window_tpot(50) or 0.0)
        g("slo.window_tpot_p99_s", lambda: self.window_tpot(99) or 0.0)
        g("slo.queue_depth_ewma", lambda: self.queue_depth.get())
        g("slo.arrival_rate_ewma", lambda: self.arrival_rate.get())
        g("slo.tpot_ewma_s", lambda: self.tpot.get())

    # -- feed points (called by the serve loop) -----------------------------
    def note_arrive(self, rid: int) -> None:
        self._arrive_ts[rid] = time.perf_counter()
        self._pending_arrivals += 1

    def note_first_token(self, rid: int) -> None:
        t0 = self._arrive_ts.get(rid)
        if t0 is not None and rid not in self._first_ts:
            now = time.perf_counter()
            self._first_ts[rid] = now
            self._ttft.append(now - t0)

    def note_finish(self, rid: int, met: bool, tokens: int) -> None:
        """Finish OR reject (met=False) — one window sample either way."""
        first = self._first_ts.pop(rid, None)
        self._arrive_ts.pop(rid, None)
        if first is not None and tokens > 1:
            tpot = (time.perf_counter() - first) / (tokens - 1)
            self._tpot.append(tpot)
            self.tpot.update(tpot)
            self.tpot_ref.update(tpot)
        self._met.append(bool(met))

    def note_queue_depth(self, depth: int) -> None:
        self.queue_depth.update(depth)

    def advance(self, steps: int) -> None:
        """Fold arrivals seen since the last call into the per-step
        arrival-rate EWMA; call once per scheduler cycle with the decode
        steps the cycle covered."""
        if steps > 0:
            self.arrival_rate.update(self._pending_arrivals / steps)
            self._pending_arrivals = 0

    # -- window reductions --------------------------------------------------
    def window_goodput(self) -> Optional[float]:
        if not self._met:
            return None
        return sum(self._met) / len(self._met)

    def window_ttft(self, p: float) -> Optional[float]:
        return percentile(list(self._ttft), p)

    def window_tpot(self, p: float) -> Optional[float]:
        return percentile(list(self._tpot), p)

    def tpot_slowdown(self) -> float:
        """Fast/slow TPOT EWMA ratio minus 1, clipped to [-0.25, 0.25] —
        deliberately small so wall-clock jitter cannot dominate the
        predictor's otherwise step-clock-deterministic features."""
        if self.tpot.value is None or not self.tpot_ref.get():
            return 0.0
        r = self.tpot.value / self.tpot_ref.value - 1.0
        return max(-0.25, min(0.25, r))


# ---------------------------------------------------------------------------
# Span tracer (Chrome trace-event JSON) + per-request lifecycle records
# ---------------------------------------------------------------------------
class _RequestRecord:
    """One request *incarnation*: re-offering the same rid (warm bench
    passes) opens a fresh record, so repeat traffic never merges."""

    __slots__ = ("rid", "arrive_ts", "arrive_step", "deadline_step",
                 "admit_ts", "admit_step", "first_token_ts", "finish_ts",
                 "finish_step", "tokens", "rejected", "resumed",
                 "preemptions", "defers")

    def __init__(self, rid: int, ts: float, step: int,
                 deadline_step: Optional[int]):
        self.rid = rid
        self.arrive_ts = ts
        self.arrive_step = step
        self.deadline_step = deadline_step
        self.admit_ts: Optional[float] = None
        self.admit_step: Optional[int] = None
        self.first_token_ts: Optional[float] = None
        self.finish_ts: Optional[float] = None
        self.finish_step: Optional[int] = None
        self.tokens = 0
        self.rejected = False
        self.resumed = 0
        self.preemptions = 0
        self.defers = 0


class Tracer:
    """Span-based request-lifecycle tracer on a monotonic clock.

    Purely host-side bookkeeping — never touches device state, so enabling
    it cannot change tokens. Events are Chrome trace-event dicts
    (timestamps in microseconds since tracer construction):

    * ``X`` complete spans (``span()``/``req_span()`` context managers),
    * ``i`` instant events (``instant()`` and the ``req_*`` lifecycle),
    * ``M`` metadata (process/track names, emitted lazily per track).

    Track layout: pid 0, tid 0 = the serving engine (admission waves,
    prefill chunks, fused rounds, decode spans); tid ``1 + rid`` = one
    track per request. ``args.step`` carries the decode-step clock where
    known, so goodput is computable from the trace alone.
    """

    enabled = True

    def __init__(self, name: str = "serve"):
        self._t0 = time.perf_counter()
        self.events: List[dict] = []
        self._named_tracks = set()
        self._reqs: List[_RequestRecord] = []
        self._open: Dict[int, _RequestRecord] = {}
        self.events.append({"ph": "M", "name": "process_name", "pid": 0,
                            "tid": 0, "args": {"name": name}})
        self._track_name(0, "engine")

    # -- low-level events ---------------------------------------------------
    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _track_name(self, tid: int, name: str) -> None:
        if tid in self._named_tracks:
            return
        self._named_tracks.add(tid)
        self.events.append({"ph": "M", "name": "thread_name", "pid": 0,
                            "tid": tid, "args": {"name": name}})

    def instant(self, name: str, *, tid: int = 0,
                args: Optional[dict] = None) -> None:
        ev = {"ph": "i", "name": name, "pid": 0, "tid": tid,
              "ts": self._now_us(), "s": "t"}
        if args:
            ev["args"] = args
        self.events.append(ev)

    @contextlib.contextmanager
    def span(self, name: str, *, tid: int = 0,
             args: Optional[dict] = None):
        """Record one ``X`` complete event around the body."""
        t0 = self._now_us()
        try:
            yield
        finally:
            ev = {"ph": "X", "name": name, "pid": 0, "tid": tid,
                  "ts": t0, "dur": max(0.0, self._now_us() - t0)}
            if args:
                ev["args"] = args
            self.events.append(ev)

    def pager_span(self, name: str, t_start: float, t_end: float,
                   args: Optional[dict] = None) -> None:
        """Record a RETROSPECTIVE span on the pager track from two
        ``time.perf_counter`` stamps. The async pager enqueues a transfer
        mid-cycle and only learns its completion at the next drain point,
        so it cannot use the context-manager form — it closes the span
        after the fact. Lands on :data:`PAGER_TID` because these spans
        intentionally overlap engine decode spans."""
        self._track_name(PAGER_TID, "pager")
        ev = {"ph": "X", "name": name, "pid": 0, "tid": PAGER_TID,
              "ts": max(0.0, (t_start - self._t0) * 1e6),
              "dur": max(0.0, (t_end - t_start) * 1e6)}
        if args:
            ev["args"] = args
        self.events.append(ev)

    # -- request lifecycle --------------------------------------------------
    def _rec(self, rid: int) -> Optional[_RequestRecord]:
        return self._open.get(rid)

    def _req_tid(self, rid: int) -> int:
        tid = 1 + rid
        self._track_name(tid, f"req {rid}")
        return tid

    def req_span(self, rid: int, name: str,
                 args: Optional[dict] = None):
        return self.span(name, tid=self._req_tid(rid), args=args)

    def _req_instant(self, rid: int, name: str, step: Optional[int],
                     **extra) -> None:
        args = dict(extra)
        if step is not None:
            args["step"] = step
        self.instant(name, tid=self._req_tid(rid), args=args or None)

    def req_arrive(self, rid: int, step: int,
                   deadline_step: Optional[int] = None) -> None:
        rec = _RequestRecord(rid, time.perf_counter(), step, deadline_step)
        self._reqs.append(rec)
        self._open[rid] = rec
        self._req_instant(rid, "arrive", step, deadline=deadline_step)

    def req_admit(self, rid: int, step: int, *,
                  resumed: bool = False) -> None:
        rec = self._rec(rid)
        if rec is not None:
            if resumed:
                rec.resumed += 1
            elif rec.admit_ts is None:
                rec.admit_ts = time.perf_counter()
                rec.admit_step = step
        self._req_instant(rid, "resume" if resumed else "admit", step)

    def req_defer(self, rid: int, step: int) -> None:
        rec = self._rec(rid)
        if rec is not None:
            rec.defers += 1
        self._req_instant(rid, "defer", step)

    def req_reject(self, rid: int, step: int, reason: str = "") -> None:
        rec = self._open.pop(rid, None)
        if rec is not None:
            rec.rejected = True
            rec.finish_ts = time.perf_counter()
            rec.finish_step = step
        self._req_instant(rid, "reject", step, reason=reason)

    def req_preempt(self, rid: int, step: int) -> None:
        rec = self._rec(rid)
        if rec is not None:
            rec.preemptions += 1
        self._req_instant(rid, "preempt", step)

    def req_first_token(self, rid: int) -> None:
        rec = self._rec(rid)
        if rec is not None and rec.first_token_ts is None:
            rec.first_token_ts = time.perf_counter()
        self._req_instant(rid, "first_token", None)

    def req_finish(self, rid: int, step: int, tokens: int) -> None:
        rec = self._open.pop(rid, None)
        if rec is not None:
            rec.finish_ts = time.perf_counter()
            rec.finish_step = step
            rec.tokens = tokens
        self._req_instant(rid, "finish", step, tokens=tokens)

    # -- SLO reduction ------------------------------------------------------
    def request_stats(self) -> List[dict]:
        """Per-request-incarnation lifecycle metrics derived from the
        recorded events: TTFT/TPOT on the monotonic wall clock, deadline
        outcome on the decode-step clock."""
        out = []
        for r in self._reqs:
            finished = r.finish_ts is not None and not r.rejected
            ttft = (r.first_token_ts - r.arrive_ts
                    if r.first_token_ts is not None else None)
            tpot = None
            if finished and r.first_token_ts is not None and r.tokens > 1:
                tpot = (r.finish_ts - r.first_token_ts) / (r.tokens - 1)
            if r.deadline_step is None:
                met = finished
            else:
                met = (finished and r.finish_step is not None
                       and r.finish_step <= r.deadline_step)
            out.append({"rid": r.rid, "arrive_step": r.arrive_step,
                        "deadline_step": r.deadline_step,
                        "finish_step": r.finish_step, "tokens": r.tokens,
                        "finished": finished, "rejected": r.rejected,
                        "preemptions": r.preemptions, "defers": r.defers,
                        "resumed": r.resumed,
                        "ttft_s": ttft, "tpot_s": tpot,
                        "met_deadline": met})
        return out

    def slo_summary(self) -> dict:
        """p50/p99 TTFT + TPOT and goodput over every offered request —
        computed from trace spans, not wall-clock totals. Goodput counts a
        request as good iff it finished (unrejected) by ``deadline_step``
        on the decode-step clock; no-deadline requests are good iff they
        completed."""
        stats = self.request_stats()
        ttfts = [s["ttft_s"] for s in stats if s["ttft_s"] is not None]
        tpots = [s["tpot_s"] for s in stats if s["tpot_s"] is not None]
        n = len(stats)
        return {
            "requests": n,
            "finished": sum(1 for s in stats if s["finished"]),
            "rejected": sum(1 for s in stats if s["rejected"]),
            "preemptions": sum(s["preemptions"] for s in stats),
            "deadlined": sum(1 for s in stats
                             if s["deadline_step"] is not None),
            "deadline_misses": sum(
                1 for s in stats
                if s["deadline_step"] is not None and not s["met_deadline"]),
            "goodput": (sum(1 for s in stats if s["met_deadline"]) / n
                        if n else None),
            "ttft_p50_s": percentile(ttfts, 50),
            "ttft_p99_s": percentile(ttfts, 99),
            "tpot_p50_s": percentile(tpots, 50),
            "tpot_p99_s": percentile(tpots, 99),
        }

    # -- export -------------------------------------------------------------
    def chrome_trace(self) -> dict:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def export_chrome(self, path: str) -> str:
        """Write ``chrome://tracing`` / Perfetto-loadable JSON to ``path``."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path


class _NullSpan:
    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The ``--metrics off`` tracer: the full :class:`Tracer` surface, every
    method a no-op (span contexts are a shared reusable null context). No
    events, no request records, no clock reads — the instrumented serving
    path degenerates to attribute calls that do nothing, and since tracing
    never touches jitted code anyway, off ≡ the pre-telemetry path
    bitwise."""

    enabled = False
    events: List[dict] = []

    def instant(self, name, *, tid=0, args=None):
        pass

    def span(self, name, *, tid=0, args=None):
        return _NULL_SPAN

    def req_span(self, rid, name, args=None):
        return _NULL_SPAN

    def req_arrive(self, rid, step, deadline_step=None):
        pass

    def req_admit(self, rid, step, *, resumed=False):
        pass

    def req_defer(self, rid, step):
        pass

    def req_reject(self, rid, step, reason=""):
        pass

    def req_preempt(self, rid, step):
        pass

    def req_first_token(self, rid):
        pass

    def req_finish(self, rid, step, tokens):
        pass

    def request_stats(self):
        return []

    def slo_summary(self):
        return {}

    def chrome_trace(self):
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def pager_span(self, name, t_start, t_end, args=None):
        pass

    def export_chrome(self, path):
        """No-op export: warns and returns None instead of raising, so a
        bench/CLI that toggled ``--metrics off`` but kept its export call
        still completes (the caller can tell nothing was written from the
        ``None``)."""
        logger.warning("tracing is disabled (--metrics off); "
                       "export_chrome(%r) wrote nothing — enable "
                       "--metrics on to export a trace", path)
        return None


def make_tracer(mode: str, name: str = "serve"):
    """``"on"`` -> a live :class:`Tracer`, ``"off"`` -> :class:`NullTracer`."""
    if mode not in ("on", "off"):
        raise ValueError(f"metrics mode must be 'on' or 'off', got {mode!r}")
    return Tracer(name) if mode == "on" else NullTracer()


class MetricsSnapshotter:
    """Periodic JSONL metrics stream: one ``registry.snapshot()`` line per
    ``every`` scheduler cycles (plus whatever ``emit`` is called with).
    Lines carry the cycle count and a wall timestamp; the file is append-
    mode so restarts extend the stream."""

    def __init__(self, registry: MetricsRegistry, path: str,
                 every: int = 50):
        if every < 1:
            raise ValueError("snapshot interval must be >= 1 cycle")
        self.registry = registry
        self.path = path
        self.every = every
        self._last = -1
        self._t0 = time.perf_counter()

    def maybe_emit(self, cycle: int) -> bool:
        """Emit iff ``cycle`` entered a new ``every``-sized window."""
        if cycle // self.every == self._last // self.every \
                and self._last >= 0:
            return False
        self.emit(cycle)
        return True

    def emit(self, cycle: int) -> None:
        self._last = cycle
        line = {"cycle": cycle,
                "elapsed_s": time.perf_counter() - self._t0}
        line.update(self.registry.snapshot())
        with open(self.path, "a") as f:
            f.write(json.dumps(line) + "\n")
