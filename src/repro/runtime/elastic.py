"""Elastic scaling: restore a checkpoint onto a DIFFERENT mesh.

Checkpoints are global-shape (mesh-agnostic, see checkpoint.ckpt), so elastic
re-scaling = rebuild the mesh at the new device count, recompute shardings
from the same logical rules, and device_put each array. The only constraints
are divisibility (handled by the rules' fallbacks) and global-batch
adjustment, which the caller owns (batch is a pure function of step).
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import numpy as np

from ..checkpoint.ckpt import restore_checkpoint
from ..parallel.sharding import MeshPlan, param_shardings, plan_for_mesh


def divisor_meshes(n_devices: int) -> List[Tuple[int, int]]:
    """All (data, model) factorizations of a device count — the shapes an
    elastic job can land on."""
    out = []
    for m in range(1, n_devices + 1):
        if n_devices % m == 0:
            out.append((n_devices // m, m))
    return out


def elastic_restore(ckpt_dir: str, template, mesh) -> tuple:
    """Restore latest checkpoint resharded for ``mesh``.

    Returns (step, state, extra). ``template`` must carry the target
    shapes/dtypes (e.g. from jax.eval_shape of the init fn)."""
    plan = plan_for_mesh(mesh)
    shardings = param_shardings(template, plan)
    return restore_checkpoint(ckpt_dir, template, shardings=shardings)
