from .fault import FaultInjection, StragglerMonitor, TrainSupervisor
from .elastic import elastic_restore, divisor_meshes

__all__ = ["FaultInjection", "StragglerMonitor", "TrainSupervisor",
           "elastic_restore", "divisor_meshes"]
