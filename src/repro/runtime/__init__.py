from .fault import FaultInjection, StragglerMonitor, TrainSupervisor
from .elastic import elastic_restore, divisor_meshes
from .telemetry import (Counter, Gauge, Histogram, MetricsRegistry,
                        MetricsSnapshotter, NullTracer, Tracer,
                        default_registry, make_tracer, metric_attr,
                        percentile)

__all__ = ["FaultInjection", "StragglerMonitor", "TrainSupervisor",
           "elastic_restore", "divisor_meshes",
           "Counter", "Gauge", "Histogram", "MetricsRegistry",
           "MetricsSnapshotter", "NullTracer", "Tracer",
           "default_registry", "make_tracer", "metric_attr", "percentile"]
