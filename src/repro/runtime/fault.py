"""Fault tolerance: restart supervisor + straggler monitor.

At 1000+ nodes the MTBF of the job is minutes-to-hours; the design here is
the standard production loop:

  * every step runs under the supervisor; any exception (device loss,
    preemption, injected fault) triggers restore-from-latest-checkpoint and
    replay — the data pipeline is a pure function of the step (data.pipeline)
    so replay is exact;
  * an async CheckpointManager bounds lost work to ``interval`` steps;
  * a StragglerMonitor tracks per-step wall time and flags outliers
    (> ``threshold`` x running median) — on real pods this feeds the
    scheduler's hot-spare swap; here it writes a structured log the tests
    assert on.

``FaultInjection`` is the test hook: raise it from a step callback to
simulate a node failure at a chosen step.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import numpy as np


class FaultInjection(RuntimeError):
    """Simulated node failure."""


@dataclasses.dataclass
class StragglerRecord:
    step: int
    seconds: float
    median: float
    flagged: bool


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, window: int = 50):
        self.threshold = threshold
        self.window = window
        self.records: List[StragglerRecord] = []
        self._times: List[float] = []

    def observe(self, step: int, seconds: float) -> StragglerRecord:
        self._times.append(seconds)
        tail = self._times[-self.window:]
        med = float(np.median(tail))
        flagged = len(tail) >= 5 and seconds > self.threshold * med
        rec = StragglerRecord(step, seconds, med, flagged)
        self.records.append(rec)
        return rec

    @property
    def flagged_steps(self):
        return [r.step for r in self.records if r.flagged]

    def summary(self) -> dict:
        if not self._times:
            return {"steps": 0}
        t = np.asarray(self._times)
        return {"steps": len(t), "mean_s": float(t.mean()),
                "p50_s": float(np.percentile(t, 50)),
                "p99_s": float(np.percentile(t, 99)),
                "flagged": len(self.flagged_steps)}


class TrainSupervisor:
    """Runs ``step_fn(state, step) -> (state, metrics)`` with checkpoint/
    restart. ``state`` must be a pytree the CheckpointManager can save.

    restore_fn() -> (step, state) pulls the latest checkpoint; save_hook is
    the CheckpointManager.maybe_save bound method.
    """

    def __init__(self, *, step_fn: Callable, save_hook: Callable,
                 restore_fn: Callable, max_restarts: int = 3,
                 monitor: Optional[StragglerMonitor] = None,
                 on_restart: Optional[Callable] = None):
        self.step_fn = step_fn
        self.save_hook = save_hook
        self.restore_fn = restore_fn
        self.max_restarts = max_restarts
        self.monitor = monitor or StragglerMonitor()
        self.on_restart = on_restart
        self.restarts = 0

    def run(self, state, start_step: int, num_steps: int):
        """Returns (final_state, metrics_list). Restarts on failure."""
        step = start_step
        metrics_log = []
        while step < start_step + num_steps:
            try:
                t0 = time.time()
                state, metrics = self.step_fn(state, step)
                self.monitor.observe(step, time.time() - t0)
                metrics_log.append(metrics)
                step += 1
                self.save_hook(step, state)
            except FaultInjection as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.max_restarts} restarts") from e
                if self.on_restart:
                    self.on_restart(self.restarts, step)
                step, state = self.restore_fn()
        return state, metrics_log
